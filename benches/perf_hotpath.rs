//! Hot-path microbenchmarks (the §Perf deliverable): wall-clock of every
//! operation on a federated client's critical path, for both engines.
//!
//! L3 native targets (EXPERIMENTS.md §Perf): a ZO client step must cost
//! ~2 forward passes + noise regeneration — we report the measured
//! probe/forward ratio (theoretical floor 2.0) and the PRNG throughput.
//! PJRT numbers are request-path latencies of the AOT artifacts.
//!
//! The wide-lane section measures the SIMD-batched Philox/normals and
//! fused-AXPY walkers against the scalar walker *and* against a live
//! reimplementation of the pre-PR libm Box-Muller hot loop, so the
//! recorded speedup factor tracks this host rather than a stale
//! constant.  Every timed section also lands in `BENCH_perf_hotpath.json`
//! (machine-readable ms/op + Melem/s); the committed copy of that file
//! is the regression baseline — a calibrated baseline hard-gates a
//! full-scale run that regresses a hot section, a smoke run
//! (`FEEDSIGN_BENCH_SCALE < 1`) only soft-logs.
//!
//! Set FEEDSIGN_PERF_PJRT=0 to skip the PJRT section (e.g. CI without
//! artifacts).

mod common;

use common::*;
use feedsign::config::ExperimentConfig;
use feedsign::data::{corpus, tasks, Dataset};
use feedsign::simkit::nn::{LinearProbe, Model, ModelCfg, TransformerSim};
use feedsign::simkit::prng;
use feedsign::simkit::zo;

fn bench<F: FnMut()>(label: &str, iters: u32, mut f: F) -> f64 {
    // warmup
    for _ in 0..3 {
        f();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<44} {:>10.3} ms/op", per * 1e3);
    per
}

fn main() {
    let mut v = Verdict::new();
    let baseline = BenchJson::baseline("perf_hotpath");
    let mut bj = BenchJson::new("perf_hotpath");
    println!("== L3 native hot path ==");

    // PRNG throughput + fusion: single-core primitive costs.  These two
    // sections pin a serial zone so the now chunk-parallel drivers stay on
    // one thread — otherwise "fusion speedup" would silently measure
    // multithreading (the parallel path is benched separately below).
    let n = 1 << 20;
    let serial = prng::serial_zone();
    let mut buf = vec![0.0f32; n];
    let per = bench("philox normals (1M elems, 1 core)", 20, || {
        prng::normals_into(7, &mut buf);
    });
    let melems = n as f64 / per / 1e6;
    println!("{:<44} {melems:>10.1} Melem/s", "  -> throughput");
    v.check("prng-throughput", melems > 30.0, format!("{melems:.0} Melem/s"));
    bj.section("philox_normals_1m", per * 1e3, Some(melems));

    // fused axpy vs gen-then-add
    let w = prng::normals_vec(1, n);
    let mut out = vec![0.0f32; n];
    let fused = bench("fused axpy_into (1M params, 1 core)", 20, || {
        zo::axpy_into(&w, &mut out, 3, 1e-3);
    });
    let unfused = bench("materialize z then axpy (1M params)", 20, || {
        let z = prng::normals_vec(3, n);
        for i in 0..n {
            out[i] = w[i] + 1e-3 * z[i];
        }
    });
    println!("  -> fusion speedup: {:.2}x (plus zero transient allocation)", unfused / fused);
    bj.section("fused_axpy_1m", fused * 1e3, Some(n as f64 / fused / 1e6));
    bj.metric("fusion_speedup", unfused / fused);

    // wide lanes: the SIMD-batched walkers vs the scalar walker vs the
    // pre-PR libm Box-Muller loop (reconstructed live in this bench so
    // the factor is measured on this host).  Outputs are pinned
    // bit-identical across dispatch widths — asserted here on the very
    // buffers being timed, and property-pinned in simkit::prng/zo.
    let width = prng::simd_width();
    println!("\n== wide lanes (SIMD-batched Philox/AXPY, dispatch {width:?}) ==");
    v.check(
        "wide-dispatch-active",
        width != prng::SimdWidth::Scalar,
        format!("runtime dispatch is {width:?} (override: FEEDSIGN_SIMD)"),
    );
    bj.note("simd_width", &format!("{width:?}"));
    let mut wide_buf = vec![0.0f32; n];
    let scalar_n = bench("normals 1M, scalar walker", 20, || {
        prng::normals_into_span_w(7, 0, &mut buf, prng::SimdWidth::Scalar);
    });
    let wide_n = bench("normals 1M, wide walker", 20, || {
        prng::normals_into_span_w(7, 0, &mut wide_buf, width);
    });
    let libm_n = bench("normals 1M, libm box-muller (pre-PR)", 20, || {
        libm_normals_into(7, &mut out);
    });
    assert!(
        buf.iter().zip(&wide_buf).all(|(a, b)| a.to_bits() == b.to_bits()),
        "wide walker must be bit-identical to the scalar walker"
    );
    println!(
        "  -> wide vs scalar: {:.2}x | vs pre-PR libm: {:.2}x",
        scalar_n / wide_n,
        libm_n / wide_n
    );
    bj.section("wide_normals_1m", wide_n * 1e3, Some(n as f64 / wide_n / 1e6));
    bj.section("scalar_normals_1m", scalar_n * 1e3, Some(n as f64 / scalar_n / 1e6));
    bj.section("libm_normals_1m", libm_n * 1e3, Some(n as f64 / libm_n / 1e6));
    bj.metric("normals_speedup_vs_prepr", libm_n / wide_n);

    let axpy_scalar = bench("fused axpy 1M, scalar walker", 20, || {
        zo::axpy_span_w(&w, &mut buf, 3, 1e-3, 0, prng::SimdWidth::Scalar);
    });
    let axpy_wide = bench("fused axpy 1M, wide walker", 20, || {
        zo::axpy_span_w(&w, &mut wide_buf, 3, 1e-3, 0, width);
    });
    let axpy_libm = bench("fused axpy 1M, libm box-muller (pre-PR)", 20, || {
        libm_axpy(&w, &mut out, 3, 1e-3);
    });
    assert!(
        buf.iter().zip(&wide_buf).all(|(a, b)| a.to_bits() == b.to_bits()),
        "wide AXPY must be bit-identical to the scalar AXPY"
    );
    println!(
        "  -> wide vs scalar: {:.2}x | vs pre-PR libm: {:.2}x",
        axpy_scalar / axpy_wide,
        axpy_libm / axpy_wide
    );
    bj.section("wide_axpy_1m", axpy_wide * 1e3, Some(n as f64 / axpy_wide / 1e6));
    bj.section("scalar_axpy_1m", axpy_scalar * 1e3, Some(n as f64 / axpy_scalar / 1e6));
    bj.section("libm_axpy_1m", axpy_libm * 1e3, Some(n as f64 / axpy_libm / 1e6));
    bj.metric("axpy_speedup_vs_prepr", axpy_libm / axpy_wide);
    bj.metric("axpy_wide_vs_scalar", axpy_scalar / axpy_wide);
    // the acceptance target (>=2x over the pre-PR transcendentals) is a
    // hard gate only at full scale on a quiet host; smoke runs soft-log
    if scale() >= 1.0 {
        v.check(
            "wide-normals-2x-over-prepr",
            libm_n / wide_n >= 2.0,
            format!("{:.2}x vs pre-PR libm", libm_n / wide_n),
        );
        v.check(
            "wide-axpy-2x-over-prepr",
            axpy_libm / axpy_wide >= 2.0,
            format!("{:.2}x vs pre-PR libm", axpy_libm / axpy_wide),
        );
        v.check(
            "wide-axpy-beats-scalar",
            axpy_wide <= axpy_scalar * 1.05,
            format!("{:.2}x over the scalar walker", axpy_scalar / axpy_wide),
        );
    } else {
        println!(
            "(wide-lane >=2x gates run at FEEDSIGN_BENCH_SCALE >= 1; \
             smoke factors: normals {:.2}x, axpy {:.2}x vs pre-PR)",
            libm_n / wide_n,
            axpy_libm / axpy_wide
        );
    }
    drop(serial);

    // transformer probe vs forward: the paper's "ZO = 2 inferences" claim
    let cfg = ModelCfg::new(64, 32, 2, 4, 16);
    let mut model = TransformerSim::new(cfg.clone());
    let w = model.init(0);
    let data = corpus::generate(&corpus::GrammarSpec::default(), 64, 16, 64, 0);
    let batch = Dataset::gather(&data, &(0..8).collect::<Vec<_>>());
    let fwd = bench("transformer forward (28k params, B=8)", 50, || {
        model.loss(&w, &batch);
    });
    let mut scratch = Vec::new();
    let probe = bench("transformer SPSA probe", 50, || {
        zo::spsa_probe_scratch(&mut model, &w, &mut scratch, &batch, 5, 1e-3);
    });
    let ratio = probe / fwd;
    println!("  -> probe/forward ratio: {ratio:.2} (floor 2.0)");
    // 3.0 cap: wallclock ratio is noisy on a shared single core
    v.check("probe-near-two-forwards", ratio < 3.0, format!("{ratio:.2}x"));
    bj.section("transformer_forward", fwd * 1e3, None);
    bj.section("transformer_probe", probe * 1e3, None);

    let mut grad = vec![0.0f32; w.len()];
    let bp = bench("transformer loss+grad (FO step)", 50, || {
        model.loss_and_grad(&w, &batch, &mut grad);
    });
    println!("  -> backprop/forward ratio: {:.2}", bp / fwd);

    // linear-probe client step (the vision bench hot path)
    let mut probe_model = LinearProbe::new(128, 10);
    let wp = probe_model.init(0);
    let vdata = feedsign::data::vision::generate(&feedsign::data::vision::SYNTH_CIFAR10, 64, 0);
    let vbatch = vdata.gather(&(0..16).collect::<Vec<_>>());
    let mut scratch2 = Vec::new();
    bench("linear-probe SPSA step (1290 params)", 2000, || {
        zo::spsa_probe_scratch(&mut probe_model, &wp, &mut scratch2, &vbatch, 9, 1e-3);
    });

    // LM task generation (bench-harness overhead)
    bench("synth task generation (512 samples)", 10, || {
        tasks::generate(&tasks::OPT_TASKS[0], 48, 12, 512, 3);
    });

    // chunk-parallel PRNG: explicit threads=1 vs threads=cores on the
    // 1M-element fused AXPY (bit-identical outputs, wall-clock only)
    let cores = std::thread::available_parallelism().map_or(1, |p| p.get());
    println!("\n== chunk-parallel noise (counter-space split, {cores} cores) ==");
    let w_axpy = prng::normals_vec(1, n);
    let mut out_axpy = vec![0.0f32; n];
    let axpy1 = bench("axpy_into 1M params, threads=1", 20, || {
        zo::axpy_into_threads(&w_axpy, &mut out_axpy, 3, 1e-3, 1);
    });
    let axpyn = bench(&format!("axpy_into 1M params, threads={cores}"), 20, || {
        zo::axpy_into_threads(&w_axpy, &mut out_axpy, 3, 1e-3, cores);
    });
    println!("  -> chunk-parallel speedup: {:.2}x", axpy1 / axpyn);

    // parallel round engine: per-round wall-clock at K clients, sequential
    // baseline vs scoped client fan-out (plan/execute/commit; bit-identical
    // runs, pinned by rust/tests/parallel_parity.rs)
    println!("\n== parallel round engine (K-client fan-out, {cores} cores) ==");
    // whether this binary carries the obs instrumentation (compiled in
    // but runtime-disabled here) — the CI disabled-overhead gate diffs
    // the round sections below between an obs-on and a
    // --no-default-features build of this same bench
    bj.note("obs_compiled", if cfg!(feature = "obs") { "on" } else { "off" });
    let mut speedup_k20 = 0.0f64;
    for (k, rounds) in [(5usize, 40u64), (20, 16), (100, 4)] {
        let seq = time_rounds(&round_cfg(k, 1), rounds);
        let par = time_rounds(&round_cfg(k, cores), rounds);
        let speedup = seq / par;
        println!(
            "K={k:<4} seq {:>8.2} ms/round | fan-out {:>8.2} ms/round | speedup {speedup:.2}x",
            seq * 1e3,
            par * 1e3
        );
        bj.section(&format!("round_k{k}_seq"), seq * 1e3, None);
        bj.section(&format!("round_k{k}_fanout"), par * 1e3, None);
        if k == 20 {
            speedup_k20 = speedup;
        }
    }
    // assert only on full-scale runs: FEEDSIGN_BENCH_SCALE < 1 marks a
    // smoke run (e.g. the CI job on shared runners), where wall-clock
    // ratios are too noisy for a hard exit-code gate
    if cores >= 4 && scale() >= 1.0 {
        v.check(
            "round-engine-2x-at-k20",
            speedup_k20 >= 2.0,
            format!("{speedup_k20:.2}x at K=20 on {cores} cores"),
        );
    } else {
        println!(
            "(round-engine >=2x shape check needs >=4 cores and full scale; \
             host has {cores}, scale {:.2})",
            scale()
        );
    }

    // replica-plane commit: the dense layout applied the aggregated
    // update K times (once per client replica); the copy-on-write store
    // applies it once to the canonical buffer.  Measured single-core so
    // the ratio is the algorithmic K-fold saving, not multithreading.
    println!("\n== replica-plane commit (once vs K dense AXPYs) ==");
    let serial2 = prng::serial_zone();
    let d_commit = 1 << 16;
    let k_commit = 100usize;
    let mut canonical = prng::normals_vec(4, d_commit);
    let once = bench("commit once: canonical AXPY (65k params)", 50, || {
        zo::apply_update(&mut canonical, 9, 1e-3);
    });
    let mut dense: Vec<Vec<f32>> = (0..k_commit).map(|_| canonical.clone()).collect();
    let dense_t = bench(&format!("commit dense: K={k_commit} per-client AXPYs"), 5, || {
        for w in &mut dense {
            zo::apply_update(w, 9, 1e-3);
        }
    });
    drop(serial2);
    let commit_speedup = dense_t / once;
    println!("  -> once-vs-K commit speedup: {commit_speedup:.1}x (theoretical {k_commit}x)");
    v.check(
        "replica-commit-once-beats-dense",
        commit_speedup >= k_commit as f64 / 4.0,
        format!("{commit_speedup:.1}x at K={k_commit}"),
    );
    // end-to-end contract: a live session commits exactly one canonical
    // AXPY per round and holds one d-float buffer for the whole pool
    let mut s = round_cfg(20, 0).build_session().expect("config builds");
    for t in 0..5 {
        s.step(t);
    }
    let st = s.replica_stats();
    v.check(
        "replica-commit-once-per-round",
        st.canonical_commits == 5 && st.peak_bytes == 4 * st.d,
        format!(
            "{} commits over 5 rounds; peak {} B vs dense {} B (K=20)",
            st.canonical_commits, st.peak_bytes, st.dense_bytes
        ),
    );

    // probe batching: canonical-buffer reads per round.  A sequential
    // worker over K FeedSign clients shares seed = t, so the engine
    // streams the canonical buffer ONCE per round where the unbatched
    // loop streamed it twice per client (2K) — counted live by the
    // session, so this is the measured reduction, not a model.
    println!("\n== execute-phase probe batching (canonical passes) ==");
    let mut pb = round_cfg(20, 1).build_session().expect("config builds");
    for t in 0..5 {
        pb.step(t);
    }
    let ps = pb.probe_stats;
    let reduction = ps.unbatched_passes() as f64 / ps.canonical_passes.max(1) as f64;
    println!(
        "K=20, 5 rounds: {} probes in {} canonical passes (unbatched: {}) -> {reduction:.1}x \
         fewer buffer streams",
        ps.probes,
        ps.canonical_passes,
        ps.unbatched_passes()
    );
    v.check(
        "probe-batching-reduces-passes",
        ps.canonical_passes < ps.unbatched_passes(),
        format!("{} vs {} passes", ps.canonical_passes, ps.unbatched_passes()),
    );
    bj.metric("probe_canonical_passes", ps.canonical_passes as f64);
    bj.metric("probe_unbatched_passes", ps.unbatched_passes() as f64);
    bj.metric("probe_pass_reduction", reduction);

    // fused commit+probe sweep (the tiled parameter plane): one
    // read-modify-write walk of the canonical applies the round-t commit
    // AND renders both round-t+1 probe views, where the flat engine paid
    // 1 + views separate full-buffer passes.  Noise work is identical on
    // both sides (same Philox streams); the win is memory traffic — the
    // canonical tile stays cache-resident across all three applications.
    println!("\n== fused commit+probe sweep (1 pass vs 1+views passes) ==");
    let serial3 = prng::serial_zone();
    let tile = prng::tile_elems();
    let mut sweep_speedup_full = 0.0f64;
    for (dn, name, iters) in [(1usize << 20, "1m", 10u32), (1 << 24, "16m", 3)] {
        let mut canon_fused = prng::normals_vec(11, dn);
        let mut canon_multi = canon_fused.clone();
        let (mut plus, mut minus) = (vec![0.0f32; dn], vec![0.0f32; dn]);
        let (mut plus2, mut minus2) = (vec![0.0f32; dn], vec![0.0f32; dn]);
        let fused_t = bench(&format!("fused sweep {name} (commit + 2 views, 1 pass)"), iters, || {
            let mut outs = [plus.as_mut_slice(), minus.as_mut_slice()];
            zo::fused_commit_probe_threads(
                &mut canon_fused,
                &[(9, 1e-3)],
                &[(10, 1e-3), (10, -1e-3)],
                &mut outs,
                tile,
                1,
            );
        });
        let multi_t = bench(&format!("multipass {name} (commit, +view, -view)"), iters, || {
            zo::perturb_in_place_threads(&mut canon_multi, 9, -1e-3, 1);
            zo::axpy_into_threads(&canon_multi, &mut plus2, 10, 1e-3, 1);
            zo::axpy_into_threads(&canon_multi, &mut minus2, 10, -1e-3, 1);
        });
        assert!(
            canon_fused.iter().zip(&canon_multi).all(|(a, b)| a.to_bits() == b.to_bits())
                && plus.iter().zip(&plus2).all(|(a, b)| a.to_bits() == b.to_bits())
                && minus.iter().zip(&minus2).all(|(a, b)| a.to_bits() == b.to_bits()),
            "fused sweep must be bit-identical to the multipass reference"
        );
        let speedup = multi_t / fused_t;
        println!("  -> fused vs multipass at {name}: {speedup:.2}x (tile {tile})");
        bj.section(&format!("fused_sweep_{name}"), fused_t * 1e3, Some(dn as f64 / fused_t / 1e6));
        bj.section(
            &format!("multipass_sweep_{name}"),
            multi_t * 1e3,
            Some(dn as f64 / multi_t / 1e6),
        );
        bj.metric(&format!("fused_sweep_speedup_{name}"), speedup);
        if dn == 1 << 24 {
            sweep_speedup_full = speedup;
        }
    }
    drop(serial3);
    // the acceptance target: a full-scale sweep at 16M params (past any
    // cache) must beat the 3-pass flat path by >=1.3x; smoke runs soft-log
    if scale() >= 1.0 {
        v.check(
            "fused-sweep-1p3x-over-multipass",
            sweep_speedup_full >= 1.3,
            format!("{sweep_speedup_full:.2}x at 16M params, tile {tile}"),
        );
    } else {
        println!(
            "(fused-sweep >=1.3x gate runs at FEEDSIGN_BENCH_SCALE >= 1; \
             smoke factor: {sweep_speedup_full:.2}x)"
        );
    }

    // PJRT request path
    if std::env::var("FEEDSIGN_PERF_PJRT").as_deref() != Ok("0")
        && feedsign::runtime::artifacts_available()
    {
        println!("\n== PJRT request path (AOT artifacts, CPU) ==");
        let model = feedsign::runtime::PjrtModel::load(&feedsign::runtime::artifacts_dir(), "tiny")
            .expect("artifacts");
        let w = model.init_params(0);
        let cols = model.entry.seq_len + 1;
        let data: Vec<u32> =
            (0..model.entry.batch_probe * cols).map(|i| (i % model.entry.vocab) as u32).collect();
        let batch = feedsign::data::Batch::Tokens { data, rows: model.entry.batch_probe, cols };
        bench("pjrt spsa_probe (tiny, 0.12M)", 10, || {
            model.spsa_probe(&w, &batch, 1, 1e-3).unwrap();
        });
        let mut wmut = w.clone();
        bench("pjrt update (tiny)", 10, || {
            model.update(&mut wmut, 1, 1e-3).unwrap();
        });
        let edata: Vec<u32> =
            (0..model.entry.batch_eval * cols).map(|i| (i % model.entry.vocab) as u32).collect();
        let ebatch = feedsign::data::Batch::Tokens { data: edata, rows: model.entry.batch_eval, cols };
        bench("pjrt eval (tiny)", 10, || {
            model.eval(&w, &ebatch).unwrap();
        });
    } else {
        println!("\n(PJRT section skipped)");
    }

    // regression gate against the committed BENCH_perf_hotpath.json:
    // armed only when that baseline is calibrated (written by a
    // full-scale run) AND this run is itself full-scale — smoke runs and
    // hand-seeded estimate baselines soft-log instead of failing
    if let Some(base) = &baseline {
        let armed = feedsign::util::bench::regression_gate_armed(base, scale());
        for (section, now_ms) in [
            ("wide_normals_1m", wide_n * 1e3),
            ("wide_axpy_1m", axpy_wide * 1e3),
            ("philox_normals_1m", per * 1e3),
            ("fused_axpy_1m", fused * 1e3),
        ] {
            let Some(base_ms) = BenchJson::baseline_ms(base, section) else { continue };
            let regressed = now_ms > base_ms * 1.5;
            let detail = format!("{section}: {now_ms:.3} ms/op vs baseline {base_ms:.3}");
            if armed {
                v.check(&format!("no-regression-{section}"), !regressed, detail);
            } else if regressed {
                println!("[perf-note] {detail} (uncalibrated baseline or smoke run: not gating)");
            }
        }
    }
    bj.write();
    v.finish()
}

/// The pre-PR Box-Muller, via libm transcendentals — the denominator of
/// the wide-lane speedup claim.  Reconstructed live in the bench (not
/// kept in the library) so the factor is measured on the same host with
/// the same flags every run instead of against a stale constant.
fn libm_box_muller(u1: f32, u2: f32) -> (f32, f32) {
    let r = (-2.0 * u1.ln()).sqrt();
    let theta = 2.0 * std::f32::consts::PI * u2;
    (r * theta.cos(), r * theta.sin())
}

/// Pre-PR normals loop: one Philox block -> four libm Box-Muller normals.
fn libm_normals_into(seed: u32, out: &mut [f32]) {
    let mut ctr = 0u32;
    let mut i = 0usize;
    while i < out.len() {
        let x = prng::philox4x32(seed, ctr);
        let (z0, z1) = libm_box_muller(prng::u32_to_unit(x[0]), prng::u32_to_unit(x[1]));
        let (z2, z3) = libm_box_muller(prng::u32_to_unit(x[2]), prng::u32_to_unit(x[3]));
        let block = [z0, z1, z2, z3];
        let take = (out.len() - i).min(4);
        out[i..i + take].copy_from_slice(&block[..take]);
        i += take;
        ctr = ctr.wrapping_add(1);
    }
}

/// Pre-PR fused AXPY loop over the same libm Box-Muller stream.
fn libm_axpy(w: &[f32], out: &mut [f32], seed: u32, scale: f32) {
    let mut ctr = 0u32;
    let mut i = 0usize;
    while i < w.len() {
        let x = prng::philox4x32(seed, ctr);
        let (z0, z1) = libm_box_muller(prng::u32_to_unit(x[0]), prng::u32_to_unit(x[1]));
        let (z2, z3) = libm_box_muller(prng::u32_to_unit(x[2]), prng::u32_to_unit(x[3]));
        let block = [z0, z1, z2, z3];
        let take = (w.len() - i).min(4);
        for ((o, wv), z) in out[i..i + take].iter_mut().zip(&w[i..i + take]).zip(&block[..take]) {
            *o = *wv + scale * *z;
        }
        i += take;
        ctr = ctr.wrapping_add(1);
    }
}

/// Bench-LM FeedSign session config for the round-engine sweep.
fn round_cfg(k: usize, threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("perf-round-k{k}-t{threads}"),
        model: bench_lm(),
        task: lm_task("synth-sst2"),
        algorithm: "feedsign".into(),
        clients: k,
        rounds: 1,
        eta: 1e-3,
        mu: 1e-3,
        batch_size: 8,
        eval_every: 0,
        eval_batches: 2,
        eval_batch_size: 16,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 5,
        verbose: false,
    }
}

/// Mean seconds per round over `rounds` steps (after one warmup round).
fn time_rounds(cfg: &ExperimentConfig, rounds: u64) -> f64 {
    let mut s = cfg.build_session().expect("config builds");
    s.step(0);
    let t0 = std::time::Instant::now();
    for t in 1..=rounds {
        s.step(t);
    }
    t0.elapsed().as_secs_f64() / rounds as f64
}
