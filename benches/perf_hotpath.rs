//! Hot-path microbenchmarks (the §Perf deliverable): wall-clock of every
//! operation on a federated client's critical path, for both engines.
//!
//! L3 native targets (EXPERIMENTS.md §Perf): a ZO client step must cost
//! ~2 forward passes + noise regeneration — we report the measured
//! probe/forward ratio (theoretical floor 2.0) and the PRNG throughput.
//! PJRT numbers are request-path latencies of the AOT artifacts.
//!
//! Set FEEDSIGN_PERF_PJRT=0 to skip the PJRT section (e.g. CI without
//! artifacts).

mod common;

use common::*;
use feedsign::data::{corpus, tasks, Dataset};
use feedsign::simkit::nn::{LinearProbe, Model, ModelCfg, TransformerSim};
use feedsign::simkit::prng;
use feedsign::simkit::zo;

fn bench<F: FnMut()>(label: &str, iters: u32, mut f: F) -> f64 {
    // warmup
    for _ in 0..3 {
        f();
    }
    let t0 = std::time::Instant::now();
    for _ in 0..iters {
        f();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    println!("{label:<44} {:>10.3} ms/op", per * 1e3);
    per
}

fn main() {
    let mut v = Verdict::new();
    println!("== L3 native hot path ==");

    // PRNG throughput (the shared-randomness substrate)
    let n = 1 << 20;
    let mut buf = vec![0.0f32; n];
    let per = bench("philox normals (1M elems)", 20, || {
        prng::normals_into(7, &mut buf);
    });
    let melems = n as f64 / per / 1e6;
    println!("{:<44} {melems:>10.1} Melem/s", "  -> throughput");
    v.check("prng-throughput", melems > 30.0, format!("{melems:.0} Melem/s"));

    // fused axpy vs gen-then-add
    let w = prng::normals_vec(1, n);
    let mut out = vec![0.0f32; n];
    let fused = bench("fused axpy_into (1M params)", 20, || {
        zo::axpy_into(&w, &mut out, 3, 1e-3);
    });
    let unfused = bench("materialize z then axpy (1M params)", 20, || {
        let z = prng::normals_vec(3, n);
        for i in 0..n {
            out[i] = w[i] + 1e-3 * z[i];
        }
    });
    println!("  -> fusion speedup: {:.2}x (plus zero transient allocation)", unfused / fused);

    // transformer probe vs forward: the paper's "ZO = 2 inferences" claim
    let cfg = ModelCfg::new(64, 32, 2, 4, 16);
    let mut model = TransformerSim::new(cfg.clone());
    let w = model.init(0);
    let data = corpus::generate(&corpus::GrammarSpec::default(), 64, 16, 64, 0);
    let batch = Dataset::gather(&data, &(0..8).collect::<Vec<_>>());
    let fwd = bench("transformer forward (28k params, B=8)", 50, || {
        model.loss(&w, &batch);
    });
    let mut scratch = Vec::new();
    let probe = bench("transformer SPSA probe", 50, || {
        zo::spsa_probe_scratch(&mut model, &w, &mut scratch, &batch, 5, 1e-3);
    });
    let ratio = probe / fwd;
    println!("  -> probe/forward ratio: {ratio:.2} (floor 2.0)");
    // 3.0 cap: wallclock ratio is noisy on a shared single core
    v.check("probe-near-two-forwards", ratio < 3.0, format!("{ratio:.2}x"));

    let mut grad = vec![0.0f32; w.len()];
    let bp = bench("transformer loss+grad (FO step)", 50, || {
        model.loss_and_grad(&w, &batch, &mut grad);
    });
    println!("  -> backprop/forward ratio: {:.2}", bp / fwd);

    // linear-probe client step (the vision bench hot path)
    let mut probe_model = LinearProbe::new(128, 10);
    let wp = probe_model.init(0);
    let vdata = feedsign::data::vision::generate(&feedsign::data::vision::SYNTH_CIFAR10, 64, 0);
    let vbatch = vdata.gather(&(0..16).collect::<Vec<_>>());
    let mut scratch2 = Vec::new();
    bench("linear-probe SPSA step (1290 params)", 2000, || {
        zo::spsa_probe_scratch(&mut probe_model, &wp, &mut scratch2, &vbatch, 9, 1e-3);
    });

    // LM task generation (bench-harness overhead)
    bench("synth task generation (512 samples)", 10, || {
        tasks::generate(&tasks::OPT_TASKS[0], 48, 12, 512, 3);
    });

    // PJRT request path
    if std::env::var("FEEDSIGN_PERF_PJRT").as_deref() != Ok("0")
        && feedsign::runtime::artifacts_available()
    {
        println!("\n== PJRT request path (AOT artifacts, CPU) ==");
        let model = feedsign::runtime::PjrtModel::load(&feedsign::runtime::artifacts_dir(), "tiny")
            .expect("artifacts");
        let w = model.init_params(0);
        let cols = model.entry.seq_len + 1;
        let data: Vec<u32> =
            (0..model.entry.batch_probe * cols).map(|i| (i % model.entry.vocab) as u32).collect();
        let batch = feedsign::data::Batch::Tokens { data, rows: model.entry.batch_probe, cols };
        bench("pjrt spsa_probe (tiny, 0.12M)", 10, || {
            model.spsa_probe(&w, &batch, 1, 1e-3).unwrap();
        });
        let mut wmut = w.clone();
        bench("pjrt update (tiny)", 10, || {
            model.update(&mut wmut, 1, 1e-3).unwrap();
        });
        let edata: Vec<u32> =
            (0..model.entry.batch_eval * cols).map(|i| (i % model.entry.vocab) as u32).collect();
        let ebatch = feedsign::data::Batch::Tokens { data: edata, rows: model.entry.batch_eval, cols };
        bench("pjrt eval (tiny)", 10, || {
            model.eval(&w, &ebatch).unwrap();
        });
    } else {
        println!("\n(PJRT section skipped)");
    }
    v.finish()
}
