//! Table 3 reproduction: vision last-layer FFT, K = 5, iid.
//!
//! Paper: ViT-large classifier-layer fine-tuning on CIFAR-10/100 —
//! FeedSign reaches 91.9 / 45.3, beating the ZO-from-scratch SOTA.
//! Substituted workload: linear probe on the frozen-featurizer synth
//! CIFAR analogues.  Shape assertions: FeedSign (a) far above chance on
//! both, (b) CIFAR-10 ≫ CIFAR-100 (the paper's 91.9 vs 45.3 ordering),
//! (c) in the same band as ZO-FedSGD under iid data.

mod common;

use common::*;
use feedsign::config::ExperimentConfig;

fn cfg(task: &str, algorithm: &str, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table3-{task}-{algorithm}"),
        model: vision_model(task),
        task: vision_task(task),
        algorithm: algorithm.into(),
        clients: 5,
        rounds,
        // calibrated per-algorithm (FeedSign's fixed step prefers a smaller
        // eta; ZO-FedSGD scales steps by |p| so it tolerates a larger one)
        eta: if algorithm == "feedsign" { 1e-3 } else { 2e-3 },
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        eval_batches: 8,
        eval_batch_size: 64,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 13,
        verbose: false,
    }
}

fn main() {
    // paper budgets: 2e4 (CIFAR-10) and 6e4 (CIFAR-100) steps; we default
    // to 1/2 scale and let FEEDSIGN_BENCH_SCALE restore the full budget
    let r10 = scaled(10_000);
    let r100 = scaled(20_000);
    let n = repeats();

    let mut table = Table::new(
        "Table 3: vision last-layer FFT, K=5 (synth substitute)",
        &["synth-cifar10", "synth-cifar100"],
    );
    let mut acc = std::collections::BTreeMap::new();
    for algo in ["zo-fedsgd", "feedsign"] {
        let mut cells = Vec::new();
        for (task, rounds) in [("synth-cifar10", r10), ("synth-cifar100", r100)] {
            let runs = timed(&format!("{algo}/{task}"), || run_repeats(&cfg(task, algo, rounds), n));
            let ms = best_accs(&runs);
            acc.insert((algo, task), ms.mean);
            cells.push(format!("{ms}"));
        }
        table.row(algo, cells);
    }
    table.print();
    println!("(paper: FeedSign 91.9 (5.9) on CIFAR-10, 45.3 (5.0) on CIFAR-100; ZO-SOTA 86.5 / 34.2)");

    let mut v = Verdict::new();
    let fs10 = acc[&("feedsign", "synth-cifar10")];
    let fs100 = acc[&("feedsign", "synth-cifar100")];
    let zo10 = acc[&("zo-fedsgd", "synth-cifar10")];
    v.check("cifar10-above-chance", fs10 > 30.0, format!("{fs10:.1}% vs 10% chance"));
    // 100-way ZO needs the paper's full 6e4-step budget to clear 40%; at
    // bench scale we assert clearly-above-chance (1%) with margin
    let floor100 = if scale() >= 1.0 { 4.0 } else { 1.5 };
    v.check("cifar100-above-chance", fs100 > floor100, format!("{fs100:.1}% vs 1% chance"));
    v.check("cifar10-easier", fs10 > fs100 + 10.0, format!("{fs10:.1} vs {fs100:.1}"));
    // Appendix C.1: on vision last-layer FFT FeedSign "performs closely to
    // ZO-FedSGD but cannot outperform" — same band, ZO may lead
    v.check("feedsign-comparable-to-zo", (fs10 - zo10).abs() < 20.0, format!("{fs10:.1} vs {zo10:.1}"));
    v.finish()
}
