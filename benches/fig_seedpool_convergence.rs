//! Convergence-vs-K sweep for the restricted seed space (FedKSeed /
//! FedKSeed-Pro, `seed_pool` mode), confronted with the
//! `theory::feedsign_pool` prediction.
//!
//! Restricting each round's direction to a pool of K candidate seeds
//! buys a `ceil(log2 K)`-bit ledger (vs the implicit round counter) at
//! the price of an approximation penalty that shrinks as K grows:
//! `theory::feedsign_pool` models the error floor as the unrestricted
//! FeedSign floor times `(1 + r_eff / K)`.  This bench runs the vision
//! FFT task at K ∈ {16, 256, 4096} plus the unrestricted baseline and
//! checks the measured shape:
//!
//! * every pool run learns (beats zero-shot) — convergence is retained
//!   for any K >= 2, it is the *floor* that moves;
//! * a large pool (K = 4096) lands in the unrestricted run's accuracy
//!   band — the paper-scale regime where the restriction is ~free;
//! * the per-round downlink prices at `ceil(log2 K) + 1` bits exactly;
//! * the theory floors are monotone decreasing in K toward the
//!   unrestricted floor (printed side by side with the measurements).

mod common;

use common::*;
use feedsign::config::ExperimentConfig;
use feedsign::theory;

const POOLS: [usize; 3] = [16, 256, 4096];

fn cfg(seed_pool: usize, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("fig-seedpool-k{seed_pool}"),
        model: vision_model("synth-cifar10"),
        task: vision_task("synth-cifar10"),
        algorithm: "feedsign".into(),
        clients: 5,
        rounds,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: (rounds / 5).max(1),
        eval_batches: 4,
        eval_batch_size: 64,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 23,
        verbose: false,
    }
}

fn index_bits(k: usize) -> u64 {
    let mut bits = 0u64;
    while (1usize << bits) < k {
        bits += 1;
    }
    bits.max(1)
}

fn main() {
    let rounds = scaled(2500);
    let n = repeats();
    let mut v = Verdict::new();
    let mut bj = BenchJson::new("fig_seedpool_convergence");

    let zs = zero_shot(&cfg(0, 10));
    println!("zero-shot: {zs:.1}%");

    // theory column: the predicted floor ordering the sweep confronts
    let c = theory::Constants::example();
    let base = theory::feedsign(&c, 2e-3, 0.1);
    println!("\n{:>12} | {:>12} | {:>14}", "pool K", "theory floor", "floor inflation");
    let mut prev = f32::INFINITY;
    for &k in &POOLS {
        let rf = theory::feedsign_pool(&c, 2e-3, 0.1, k);
        println!(
            "{k:>12} | {:>12.3e} | {:>13.3}x",
            rf.error_floor(),
            rf.error_floor() / base.error_floor()
        );
        v.check(
            &format!("theory-floor-monotone-k{k}"),
            rf.c < prev && rf.c > base.c,
            format!("floor C {:.3e} (unrestricted {:.3e})", rf.c, base.c),
        );
        prev = rf.c;
    }
    println!("{:>12} | {:>12.3e} | {:>13.3}x", "inf", base.error_floor(), 1.0);

    // measured column
    let mut table = Table::new(
        &format!("seed-pool convergence ({rounds} rounds, K=5 clients, vision FFT)"),
        &["best acc %", "final loss", "bits/round down"],
    );
    let unrestricted = run_repeats(&cfg(0, rounds), n);
    let base_acc = best_accs(&unrestricted);
    table.row(
        "unrestricted",
        vec![
            format!("{base_acc}"),
            format!("{:.4}", final_losses(&unrestricted).mean),
            "5x1".into(),
        ],
    );
    for &k in &POOLS {
        let runs = run_repeats(&cfg(k, rounds), n);
        let acc = best_accs(&runs);
        let bits = runs[0].ledger.downlink_bits;
        let per_round = index_bits(k) + 1;
        table.row(
            &format!("pool K={k}"),
            vec![
                format!("{acc}"),
                format!("{:.4}", final_losses(&runs).mean),
                format!("5x{per_round}"),
            ],
        );
        v.check(
            &format!("pool-k{k}-learns"),
            acc.mean > zs,
            format!("{:.1}% vs zero-shot {zs:.1}%", acc.mean),
        );
        v.check(
            &format!("pool-k{k}-downlink-prices-log2k-plus-one"),
            bits == runs[0].rounds * 5 * per_round,
            format!("{bits} bits over {} rounds x 5 x {per_round}", runs[0].rounds),
        );
        bj.metric(&format!("acc_k{k}"), acc.mean as f64);
        if k == *POOLS.last().unwrap() {
            v.check(
                "large-pool-matches-unrestricted-band",
                (base_acc.mean - acc.mean).abs() < 10.0,
                format!("K={k}: {:.1}% vs unrestricted {:.1}%", acc.mean, base_acc.mean),
            );
        }
    }
    table.print();
    bj.metric("acc_unrestricted", base_acc.mean as f64);
    bj.metric("rounds", rounds as f64);
    bj.write();
    v.finish()
}
