//! Figure 8/9 + Proposition E.2 reproduction: the inherent sign-reversing
//! probability p_{t,e} of batch gradient projections.
//!
//! Paper setup (Appendix E.2, OPT-125M/SST-2): for seeds s = 0..39, compare
//! the full-data projection z_s . grad L(w) against batch-resampled
//! projections; p_{t,e} = fraction of batches whose projection sign
//! disagrees.  We run the same protocol on the bench LM + synth-sst2 with
//! the *exact* directional derivative (full gradient dotted with z).
//!
//! Shape assertions (Prop E.2): every measured p_{t,e} <= 1/2 (+MC noise);
//! p_{t,e} shrinks as |z . grad L| grows (Fig 8's funnel shape); and the
//! batch-projection distribution is symmetric around the full-data value
//! (Fig 9, checked via skew of the samples).

mod common;

use common::*;
use feedsign::config::{ExperimentConfig, ModelSpec, TaskSpec};
use feedsign::simkit::nn::{Model, ModelCfg, TransformerSim};
use feedsign::simkit::ops::dot;
use feedsign::simkit::prng::{normals_vec, Rng};

fn main() {
    let cfg = ModelCfg::new(48, 16, 1, 2, 12);
    let mut model = TransformerSim::new(cfg.clone());

    // fine-tune a bit first so the gradient is not the random-init one
    let exp = ExperimentConfig {
        name: "fig8-warmup".into(),
        model: ModelSpec::Transformer { vocab: 48, d_model: 16, n_layers: 1, n_heads: 2, seq_len: 12 },
        task: TaskSpec::SynthLm { name: "synth-sst2".into(), train: 512, test: 128 },
        algorithm: "mezo".into(),
        clients: 1,
        rounds: scaled(300),
        eta: 1e-4,
        mu: 1e-3,
        batch_size: 8,
        eval_every: 0,
        eval_batches: 2,
        eval_batch_size: 32,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 43,
        verbose: false,
    };
    let mut session = exp.build_session().expect("builds");
    for t in 0..exp.rounds {
        session.step(t);
    }
    let w = session.replica(0).into_owned();
    let (train, _) = exp.datasets().expect("data");

    // full-data gradient
    let full = train.gather(&(0..train.len().min(512)).collect::<Vec<_>>());
    let mut grad = vec![0.0f32; w.len()];
    model.loss_and_grad(&w, &full, &mut grad);

    let n_seeds = 40u32; // paper: s = 0..39
    let n_batches = ((200.0 * scale()) as usize).max(50);
    let batch_size = 16;
    let mut rng = Rng::new(0xF18, 0);
    let mut grad_b = vec![0.0f32; w.len()];

    println!("seed, full_projection, p_te, sample_skew");
    let mut results = Vec::new();
    for s in 0..n_seeds {
        let z = normals_vec(s, w.len());
        let full_proj = dot(&z, &grad);
        let mut flips = 0usize;
        let mut samples = Vec::with_capacity(n_batches);
        for _ in 0..n_batches {
            let idx: Vec<usize> = (0..batch_size).map(|_| rng.below(train.len())).collect();
            let batch = train.gather(&idx);
            model.loss_and_grad(&w, &batch, &mut grad_b);
            let proj = dot(&z, &grad_b);
            samples.push(proj);
            if proj * full_proj < 0.0 {
                flips += 1;
            }
        }
        let p_te = flips as f32 / n_batches as f32;
        // symmetry diagnostic: standardized skew of batch projections
        let mean: f32 = samples.iter().sum::<f32>() / samples.len() as f32;
        let var: f32 =
            samples.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / samples.len() as f32;
        let skew: f32 = samples
            .iter()
            .map(|v| {
                let d = (v - mean) / var.sqrt().max(1e-9);
                d * d * d
            })
            .sum::<f32>()
            / samples.len() as f32;
        println!("{s}, {full_proj:.5}, {p_te:.4}, {skew:.3}");
        results.push((full_proj, p_te, skew));
    }

    let mut v = Verdict::new();
    let max_pte = results.iter().map(|r| r.1).fold(0.0f32, f32::max);
    // MC tolerance: 1/2 + ~3 sigma of a Bernoulli(1/2) over n_batches
    let tol = 0.5 + 3.0 * (0.25 / n_batches as f32).sqrt();
    v.check("p_te-below-half", max_pte <= tol, format!("max p_te {max_pte:.4} (tol {tol:.3}; paper max 0.4968)"));

    // funnel shape: strong projections flip less
    let mut strong: Vec<f32> = Vec::new();
    let mut weak: Vec<f32> = Vec::new();
    let med = {
        let mut m: Vec<f32> = results.iter().map(|r| r.0.abs()).collect();
        m.sort_by(|a, b| a.partial_cmp(b).unwrap());
        m[m.len() / 2]
    };
    for (proj, p, _) in &results {
        if proj.abs() >= med {
            strong.push(*p);
        } else {
            weak.push(*p);
        }
    }
    let mean = |v: &[f32]| v.iter().sum::<f32>() / v.len().max(1) as f32;
    v.check(
        "funnel-shape",
        mean(&strong) <= mean(&weak) + 0.02,
        format!("p_te strong {:.3} vs weak {:.3}", mean(&strong), mean(&weak)),
    );
    let mean_abs_skew =
        results.iter().map(|r| r.2.abs()).sum::<f32>() / results.len() as f32;
    v.check(
        "batch-projection-symmetry",
        mean_abs_skew < 1.0,
        format!("mean |skew| {mean_abs_skew:.3} (Assumption E.1)"),
    );
    v.finish()
}
