//! Table 5 reproduction: language tasks with 1 Byzantine client of K = 5.
//!
//! Paper (OPT-125M): the attacker sends a random projection (ZO-FedSGD) /
//! a reversed sign (FeedSign); FeedSign beats ZO-FedSGD on every task,
//! largest gap +6.5.  Shape assertions: (a) FeedSign's average under
//! attack >= ZO-FedSGD's average under attack; (b) FeedSign under attack
//! stays within a few points of its clean run (1/5 < majority).

mod common;

use common::*;
use feedsign::config::ExperimentConfig;

const TASKS: [&str; 7] =
    ["synth-sst2", "synth-rte", "synth-cb", "synth-boolq", "synth-wsc", "synth-wic", "synth-multirc"];

fn cfg(task: &str, algorithm: &str, byzantine: usize, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table5-{task}-{algorithm}-{byzantine}"),
        model: bench_lm(),
        task: lm_task(task),
        algorithm: algorithm.into(),
        clients: 5,
        rounds,
        eta: 3e-3,
        mu: 1e-3,
        batch_size: 8,
        eval_every: (rounds / 4).max(1),
        eval_batches: 4,
        eval_batch_size: 32,
        dirichlet_beta: None,
        byzantine_count: byzantine,
        attack: Some(if algorithm == "feedsign" {
            "sign-flip".into() // FeedSign's worst case (Remark 3.14)
        } else {
            "random-projection:20.0".into() // paper's ZO-FedSGD attacker (severity calibrated)
        }),
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 300,
        seed: 23,
        verbose: false,
    }
}

fn main() {
    let rounds = scaled(1500);
    let n = repeats();
    let mut table = Table::new(
        "Table 5: 1 Byzantine of K=5 on language tasks (synth substitute)",
        &TASKS.iter().map(|t| &t[6..]).collect::<Vec<_>>(),
    );

    let mut avg = std::collections::BTreeMap::new();
    let rows: [(&str, &str, usize); 4] = [
        ("zo-fedsgd clean", "zo-fedsgd", 0),
        ("zo-fedsgd +1byz", "zo-fedsgd", 1),
        ("feedsign clean", "feedsign", 0),
        ("feedsign +1byz", "feedsign", 1),
    ];
    for (label, algo, byz) in rows {
        let mut cells = Vec::new();
        let mut means = Vec::new();
        for task in TASKS {
            let runs = run_repeats(&cfg(task, algo, byz, rounds), n);
            let ms = best_accs(&runs);
            means.push(ms.mean);
            cells.push(format!("{ms}"));
        }
        avg.insert(label, means.iter().sum::<f32>() / means.len() as f32);
        table.row(label, cells);
    }
    table.print();
    println!("\naverages: {avg:?}");
    println!("(paper Table 5: FeedSign above ZO-FedSGD on every column, gap up to +6.5)");

    let mut v = Verdict::new();
    let fs_b = avg["feedsign +1byz"];
    let fs_c = avg["feedsign clean"];
    let zo_b = avg["zo-fedsgd +1byz"];
    // the paper's +6.5 FeedSign margin emerges at the full 6e4-step budget;
    // at reduced scale the random-walk damage to ZO-FedSGD accumulates
    // slowly, so the margin requirement is scale-aware
    let margin = if scale() >= 1.0 { -1.0 } else { -4.0 };
    v.check(
        "feedsign-beats-zo-under-attack",
        fs_b >= zo_b + margin,
        format!("feedsign {fs_b:.1} vs zo-fedsgd {zo_b:.1} with 1 attacker (margin {margin})"),
    );
    v.check(
        "feedsign-majority-absorbs-one",
        fs_b >= fs_c - 6.0,
        format!("feedsign {fs_c:.1} clean vs {fs_b:.1} attacked"),
    );
    v.finish()
}
