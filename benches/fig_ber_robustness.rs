//! BER robustness sweep: the sign-flip-tolerance claim, executable.
//!
//! The paper's Byzantine analysis bounds the damage of a flipped 1-bit
//! vote; the wireless ZO-FL follow-up line studies exactly this regime
//! over unreliable links.  This bench sweeps a binary-symmetric uplink
//! (`net::ChannelModel::BitFlip`) at BER ∈ {0, 1e-4, 1e-3, 1e-2} across
//! FeedSign / ZO-FedSGD / FedSGD on the vision last-layer FFT task and
//! reports best accuracy per cell.
//!
//! Expected shape (and the assertions below):
//! * **FeedSign degrades gracefully** — a flipped vote is at worst a
//!   single Byzantine voter for one round, so accuracy at 1e-2 stays in
//!   the band of the clean run;
//! * **dense payloads are fragile** — FedSGD ships 32·d bits per round,
//!   so at 1e-2 hundreds of gradient bits flip per message and a single
//!   flipped f32 exponent bit blows an entry up by orders of magnitude:
//!   accuracy collapses toward chance;
//! * at matched BER, FeedSign's degradation is far smaller than the
//!   dense baseline's — the robustness headline.
//!
//! The channel seed is held fixed while BER varies, so the sweep's 0
//! column is the exact ideal-channel trajectory (pinned by
//! `rust/tests/net_parity.rs`).

mod common;

use common::*;
use feedsign::config::ExperimentConfig;

const BERS: [f64; 4] = [0.0, 1e-4, 1e-3, 1e-2];
const METHODS: [&str; 3] = ["feedsign", "zo-fedsgd", "fedsgd"];

fn cfg(algorithm: &str, ber: f64, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("fig-ber-{algorithm}-{ber}"),
        model: vision_model("synth-cifar10"),
        task: vision_task("synth-cifar10"),
        algorithm: algorithm.into(),
        clients: 5,
        rounds,
        // calibrated per family: the FO baseline takes true-gradient
        // steps, the ZO methods take 1-bit / projected steps
        eta: if algorithm == "fedsgd" { 0.05 } else { 2e-3 },
        mu: 1e-3,
        batch_size: 16,
        eval_every: (rounds / 5).max(1),
        eval_batches: 4,
        eval_batch_size: 64,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: if ber == 0.0 { "ideal".into() } else { format!("ber:{ber}") },
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 17,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 41,
        verbose: false,
    }
}

fn main() {
    let rounds = scaled(3000);
    let n = repeats();
    let cols: Vec<String> = BERS.iter().map(|b| format!("ber={b}")).collect();
    let mut table = Table::new(
        &format!("BER robustness: best accuracy (%) over {rounds} rounds, K=5"),
        &cols.iter().map(String::as_str).collect::<Vec<_>>(),
    );

    let mut acc = std::collections::BTreeMap::new();
    for method in METHODS {
        let mut cells = Vec::new();
        for &ber in &BERS {
            let runs = timed(&format!("{method}@ber={ber}"), || {
                run_repeats(&cfg(method, ber, rounds), n)
            });
            let ms = best_accs(&runs);
            let flips: u64 = runs.iter().map(|r| r.net.flipped_bits).sum();
            acc.insert((method, ber.to_bits()), ms.mean);
            cells.push(format!("{ms}"));
            if ber > 0.0 {
                println!("  [{method} ber={ber}] {flips} bits flipped across {n} runs");
            }
        }
        table.row(method, cells);
    }
    table.print();
    println!("\n(claim: FeedSign's 1-bit votes are bounded-impact under bit flips —");
    println!(" the same argument that bounds a Byzantine voter — while 32·d-bit");
    println!(" dense payloads collapse once exponent bits start flipping)");

    let at = |m: &'static str, ber: f64| acc[&(m, ber.to_bits())];
    // machine-readable cells: best-acc % per (method, ber)
    let mut bj = BenchJson::new("fig_ber_robustness");
    bj.metric("rounds", rounds as f64);
    for method in METHODS {
        for &ber in &BERS {
            bj.metric(&format!("best_acc_pct.{method}.ber_{ber}"), at(method, ber) as f64);
        }
    }
    bj.write();
    let mut v = Verdict::new();
    // FeedSign degrades gracefully across the whole sweep
    let fs_drop = BERS
        .iter()
        .map(|&b| at("feedsign", 0.0) - at("feedsign", b))
        .fold(f32::MIN, f32::max);
    v.check(
        "feedsign-graceful-under-ber",
        fs_drop < 10.0,
        format!("worst FeedSign degradation {fs_drop:.1} points"),
    );
    // the dense baseline collapses at 1e-2
    let fo_drop = at("fedsgd", 0.0) - at("fedsgd", 1e-2);
    v.check(
        "fedsgd-fragile-at-1e-2",
        fo_drop > 15.0,
        format!("FedSGD degradation {fo_drop:.1} points at ber=1e-2"),
    );
    // robustness headline: at matched BER the 1-bit protocol loses far
    // less than the dense one
    v.check(
        "feedsign-degrades-less-than-dense",
        fo_drop > fs_drop + 10.0,
        format!("dense -{fo_drop:.1} vs feedsign -{fs_drop:.1} at ber=1e-2"),
    );
    // the 64-bit pair protocol sits with the fragile family once its
    // coefficient exponent bits start flipping
    let zo_drop = at("zo-fedsgd", 0.0) - at("zo-fedsgd", 1e-2);
    v.check(
        "feedsign-degrades-less-than-zo-pairs",
        zo_drop > fs_drop - 2.0,
        format!("zo -{zo_drop:.1} vs feedsign -{fs_drop:.1} at ber=1e-2"),
    );
    v.finish()
}
