//! Table 1 + Eq. 5 + §1 motivation: per-step and per-run communication
//! overhead of every method, with wall-clock projections on a mobile link.
//!
//! Fully measured on the metered protocol: runs a short session per method
//! and reads the exact ledger, then scales analytically to the paper's
//! regimes (OPT-1.3B FedAvg ≈ 48M floats/round; OPT-13B FO = 24 GB/step
//! vs FeedSign's 1 bit).

mod common;

use common::*;
use feedsign::comm::LinkModel;
use feedsign::config::ExperimentConfig;

fn cfg(algorithm: &str) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table1-{algorithm}"),
        model: vision_model("synth-cifar10"),
        task: vision_task("synth-cifar10"),
        algorithm: algorithm.into(),
        clients: if algorithm == "mezo" { 1 } else { 5 },
        rounds: 100,
        eta: 1e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        eval_batches: 1,
        eval_batch_size: 16,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 3,
        verbose: false,
    }
}

fn main() {
    let link = LinkModel::mobile();
    let mut table = Table::new(
        "Table 1: stepwise communication load (measured over 100 rounds, K=5)",
        &["up bits/step/client", "down bits/step/client", "comm s/1k steps"],
    );

    let mut v = Verdict::new();
    let mut per_method = std::collections::BTreeMap::new();
    for algo in ["fedsgd", "mezo", "zo-fedsgd", "feedsign"] {
        let c = cfg(algo);
        let k = c.clients as u64;
        let mut session = c.build_session().expect("builds");
        for t in 0..c.rounds {
            session.step(t);
        }
        let led = session.ledger.clone();
        let up_per = led.uplink_bits as f64 / (c.rounds * k) as f64;
        let down_per = led.downlink_bits as f64 / (c.rounds * k) as f64;
        let mut led_1k = led.clone();
        led_1k.uplink_bits = led.uplink_bits * 10;
        led_1k.downlink_bits = led.downlink_bits * 10;
        led_1k.uplink_msgs = led.uplink_msgs * 10;
        led_1k.downlink_msgs = led.downlink_msgs * 10;
        table.row(
            algo,
            vec![
                format!("{up_per:.0}"),
                format!("{down_per:.0}"),
                format!("{:.2}", link.seconds(&led_1k)),
            ],
        );
        per_method.insert(algo.to_string(), (up_per, down_per));
    }
    table.print();

    // paper's qualitative comparisons, scaled analytically
    let d13b: u64 = 13_000_000_000;
    println!("\nanalytic projections (paper §1 / §4):");
    println!(
        "  OPT-13B FO upload/step: {} bits = {:.1} GB  | FeedSign: 1 bit",
        32 * d13b,
        32.0 * d13b as f64 / 8e9
    );
    let d1_3b_floats = 48_000_000u64; // paper: ~48M floats per FedAvg round on OPT-1.3B
    println!(
        "  OPT-1.3B FedAvg round: {:.0} MB ≈ {:.1} min of FHD video | FeedSign: 1 bit",
        d1_3b_floats as f64 * 4.0 / 1e6,
        d1_3b_floats as f64 * 4.0 / 1e6 / 12.0 // ~12 MB/min FHD
    );

    let (fs_up, fs_down) = per_method["feedsign"];
    let (zo_up, _) = per_method["zo-fedsgd"];
    let (fo_up, _) = per_method["fedsgd"];
    let (mz_up, mz_down) = per_method["mezo"];
    v.check("feedsign-1bit-up", fs_up == 1.0, format!("{fs_up} bits/step/client"));
    v.check("feedsign-1bit-down", fs_down == 1.0, format!("{fs_down} bits/step/client"));
    v.check("zo-fedsgd-64bit", zo_up == 64.0, format!("{zo_up} bits/step/client"));
    v.check("fedsgd-32d", fo_up >= 32.0 * 1024.0, format!("{fo_up} bits/step/client (d >= 1024)"));
    v.check("mezo-centralized-no-comm", mz_up == 0.0 && mz_down == 0.0, format!("{mz_up}/{mz_down}"));
    v.finish()
}
