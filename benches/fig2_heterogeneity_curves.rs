//! Figure 2 reproduction: loss/accuracy curves vs steps under data
//! heterogeneity, K = 25 (paper: ResNet-18 FFT with Dirichlet beta = 1.0
//! shards and the 1 + N(0,1) projection-noise multiplier, Appendix H).
//!
//! Emits the two curve series (CSV to stdout + `target/fig2_*.csv`) and
//! asserts the figure's shape: both methods descend; under combined skew
//! + projection noise FeedSign's final loss is no worse than ZO-FedSGD's
//! (heterogeneity-independent floor, Remark 3.13).

mod common;

use common::*;
use feedsign::config::ExperimentConfig;

fn cfg(algorithm: &str, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("fig2-{algorithm}"),
        model: vision_model("synth-cifar10"),
        task: vision_task("synth-cifar10"),
        algorithm: algorithm.into(),
        clients: 25,
        rounds,
        eta: 1e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: (rounds / 24).max(1),
        eval_batches: 6,
        eval_batch_size: 64,
        dirichlet_beta: Some(1.0),
        byzantine_count: 0,
        attack: None,
        c_g_noise: 1.0, // the paper's high-c_g amplifier (Appendix H)
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 37,
        verbose: false,
    }
}

fn main() {
    let rounds = scaled(12_000); // paper: 1.2e5
    let mut v = Verdict::new();
    let mut finals = std::collections::BTreeMap::new();

    for algo in ["zo-fedsgd", "feedsign"] {
        let c = cfg(algo, rounds);
        let mut session = c.build_session().expect("builds");
        let result = timed(algo, || session.run());
        let csv = result.to_csv();
        let path = format!("target/fig2_{algo}.csv");
        let _ = std::fs::write(&path, &csv);
        println!("\n== Fig 2 series: {algo} (written to {path}) ==");
        println!("{csv}");
        let first = result.records.first().map(|r| r.eval_loss).unwrap_or(f32::NAN);
        finals.insert(algo.to_string(), (first, result.final_loss, result.final_acc));
    }

    let (zo_first, zo_final, zo_acc) = finals["zo-fedsgd"];
    let (fs_first, fs_final, fs_acc) = finals["feedsign"];
    println!(
        "\nfinal: zo-fedsgd loss {zo_final:.4} acc {:.1}% | feedsign loss {fs_final:.4} acc {:.1}%",
        zo_acc * 100.0,
        fs_acc * 100.0
    );
    v.check("zo-descends", zo_final < zo_first, format!("{zo_first:.3} -> {zo_final:.3}"));
    v.check("feedsign-descends", fs_final < fs_first, format!("{fs_first:.3} -> {fs_final:.3}"));
    // Remark 3.13 is a statement about error *floors*; mid-run snapshots
    // favor ZO-FedSGD's magnitude-scaled steps, so the cap is scale-aware
    let cap = if scale() >= 1.0 { 1.10 } else { 1.30 };
    v.check(
        "feedsign-floor-not-worse-under-heterogeneity",
        fs_final <= zo_final * cap,
        format!("feedsign {fs_final:.4} vs zo {zo_final:.4} (cap {cap}x)"),
    );
    v.finish()
}
