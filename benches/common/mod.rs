//! Shared harness for the table/figure reproduction benches.
//!
//! Every bench is a `harness = false` binary (the offline environment has
//! no criterion): it builds sessions through the public config API, runs
//! the method grid with repeats, prints the paper-shaped table, and exits
//! non-zero if the *shape* assertions fail (who wins, by roughly what
//! factor).  `FEEDSIGN_BENCH_SCALE` (float, default 1.0) scales round
//! budgets for quick smoke runs (e.g. 0.1) or fuller sweeps (e.g. 4.0).

#![allow(dead_code)]

use feedsign::config::{ExperimentConfig, ModelSpec, TaskSpec};
use feedsign::metrics::{mean_std, MeanStd, RunResult};

/// Round-budget scale from the environment.
pub fn scale() -> f64 {
    std::env::var("FEEDSIGN_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

pub fn scaled(rounds: u64) -> u64 {
    ((rounds as f64 * scale()) as u64).max(10)
}

/// Repeats for mean (std) cells — the paper uses 5; we default to 3 and
/// scale with the budget.
pub fn repeats() -> u32 {
    if scale() >= 2.0 {
        5
    } else if scale() >= 0.5 {
        3
    } else {
        2
    }
}

/// Default LM model for table benches: small enough that a 4-method x
/// 11-task grid finishes on one core, big enough to learn the synth tasks.
pub fn bench_lm() -> ModelSpec {
    ModelSpec::Transformer { vocab: 48, d_model: 16, n_layers: 1, n_heads: 2, seq_len: 12 }
}

pub fn lm_task(name: &str) -> TaskSpec {
    TaskSpec::SynthLm { name: name.into(), train: 512, test: 256 }
}

pub fn vision_task(name: &str) -> TaskSpec {
    TaskSpec::SynthVision { name: name.into(), train: 2000, test: 500 }
}

pub fn vision_model(name: &str) -> ModelSpec {
    ModelSpec::LinearProbe { dim: 128, classes: if name.ends_with("100") { 100 } else { 10 } }
}

/// Run one config for `n` seeds; returns per-seed best accuracies (%).
pub fn run_repeats(cfg: &ExperimentConfig, n: u32) -> Vec<RunResult> {
    (0..n)
        .map(|r| {
            let mut c = cfg.clone();
            c.seed = cfg.seed + 1000 * r;
            let mut session = c.build_session().expect("config builds");
            session.run()
        })
        .collect()
}

pub fn best_accs(runs: &[RunResult]) -> MeanStd {
    let v: Vec<f32> = runs.iter().map(|r| r.best_acc() * 100.0).collect();
    mean_std(&v)
}

pub fn final_losses(runs: &[RunResult]) -> MeanStd {
    let v: Vec<f32> = runs.iter().map(|r| r.final_loss).collect();
    mean_std(&v)
}

/// Zero-shot metric: evaluate the initial model without any training.
pub fn zero_shot(cfg: &ExperimentConfig) -> f32 {
    let mut c = cfg.clone();
    c.rounds = 1; // validation requires > 0; we evaluate without stepping
    let mut session = c.build_session().expect("config builds");
    let (_, acc) = session.evaluate();
    acc * 100.0
}

/// Pretty table printing.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<String>)>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, name: &str, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push((name.to_string(), cells));
    }

    pub fn print(&self) {
        let w0 = self
            .rows
            .iter()
            .map(|(n, _)| n.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap();
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|(_, cells)| cells[i].len())
                    .chain(std::iter::once(c.len()))
                    .max()
                    .unwrap()
            })
            .collect();
        println!("\n=== {} ===", self.title);
        print!("{:w0$}", "method");
        for (c, w) in self.columns.iter().zip(&widths) {
            print!(" | {c:>w$}");
        }
        println!();
        let total: usize = w0 + widths.iter().map(|w| w + 3).sum::<usize>();
        println!("{}", "-".repeat(total));
        for (name, cells) in &self.rows {
            print!("{name:w0$}");
            for (c, w) in cells.iter().zip(&widths) {
                print!(" | {c:>w$}");
            }
            println!();
        }
    }
}

/// Shape assertion helper: prints PASS/FAIL and tracks a global verdict.
pub struct Verdict {
    pub failures: Vec<String>,
}

impl Verdict {
    pub fn new() -> Self {
        Verdict { failures: Vec::new() }
    }

    pub fn check(&mut self, name: &str, ok: bool, detail: String) {
        if ok {
            println!("[shape-check] PASS {name}: {detail}");
        } else {
            println!("[shape-check] FAIL {name}: {detail}");
            self.failures.push(name.to_string());
        }
    }

    /// Exit the bench process with the verdict.
    pub fn finish(self) -> ! {
        if self.failures.is_empty() {
            println!("\nall shape checks passed");
            std::process::exit(0)
        } else {
            println!("\nFAILED shape checks: {:?}", self.failures);
            std::process::exit(1)
        }
    }
}

/// Wall-clock helper.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    println!("[timing] {label}: {:.1}s", t0.elapsed().as_secs_f64());
    out
}
