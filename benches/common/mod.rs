//! Shared harness for the table/figure reproduction benches.
//!
//! Every bench is a `harness = false` binary (the offline environment has
//! no criterion): it builds sessions through the public config API, runs
//! the method grid with repeats, prints the paper-shaped table, and exits
//! non-zero if the *shape* assertions fail (who wins, by roughly what
//! factor).  `FEEDSIGN_BENCH_SCALE` (float, default 1.0) scales round
//! budgets for quick smoke runs (e.g. 0.1) or fuller sweeps (e.g. 4.0).

#![allow(dead_code)]

use feedsign::config::{ExperimentConfig, ModelSpec, TaskSpec};
use feedsign::metrics::{mean_std, MeanStd, RunResult};
use feedsign::util::json::Json;
use std::collections::BTreeMap;

/// Round-budget scale from the environment.
pub fn scale() -> f64 {
    std::env::var("FEEDSIGN_BENCH_SCALE")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(1.0)
}

pub fn scaled(rounds: u64) -> u64 {
    ((rounds as f64 * scale()) as u64).max(10)
}

/// Repeats for mean (std) cells — the paper uses 5; we default to 3 and
/// scale with the budget.
pub fn repeats() -> u32 {
    if scale() >= 2.0 {
        5
    } else if scale() >= 0.5 {
        3
    } else {
        2
    }
}

/// Default LM model for table benches: small enough that a 4-method x
/// 11-task grid finishes on one core, big enough to learn the synth tasks.
pub fn bench_lm() -> ModelSpec {
    ModelSpec::Transformer { vocab: 48, d_model: 16, n_layers: 1, n_heads: 2, seq_len: 12 }
}

pub fn lm_task(name: &str) -> TaskSpec {
    TaskSpec::SynthLm { name: name.into(), train: 512, test: 256 }
}

pub fn vision_task(name: &str) -> TaskSpec {
    TaskSpec::SynthVision { name: name.into(), train: 2000, test: 500 }
}

pub fn vision_model(name: &str) -> ModelSpec {
    ModelSpec::LinearProbe { dim: 128, classes: if name.ends_with("100") { 100 } else { 10 } }
}

/// Run one config for `n` seeds; returns per-seed best accuracies (%).
pub fn run_repeats(cfg: &ExperimentConfig, n: u32) -> Vec<RunResult> {
    (0..n)
        .map(|r| {
            let mut c = cfg.clone();
            c.seed = cfg.seed + 1000 * r;
            let mut session = c.build_session().expect("config builds");
            session.run()
        })
        .collect()
}

pub fn best_accs(runs: &[RunResult]) -> MeanStd {
    let v: Vec<f32> = runs.iter().map(|r| r.best_acc() * 100.0).collect();
    mean_std(&v)
}

pub fn final_losses(runs: &[RunResult]) -> MeanStd {
    let v: Vec<f32> = runs.iter().map(|r| r.final_loss).collect();
    mean_std(&v)
}

/// Zero-shot metric: evaluate the initial model without any training.
pub fn zero_shot(cfg: &ExperimentConfig) -> f32 {
    let mut c = cfg.clone();
    c.rounds = 1; // validation requires > 0; we evaluate without stepping
    let mut session = c.build_session().expect("config builds");
    let (_, acc) = session.evaluate();
    acc * 100.0
}

/// Pretty table printing.
pub struct Table {
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<(String, Vec<String>)>,
}

impl Table {
    pub fn new(title: &str, columns: &[&str]) -> Self {
        Table {
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, name: &str, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len());
        self.rows.push((name.to_string(), cells));
    }

    pub fn print(&self) {
        let w0 = self
            .rows
            .iter()
            .map(|(n, _)| n.len())
            .chain(std::iter::once(8))
            .max()
            .unwrap();
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|(_, cells)| cells[i].len())
                    .chain(std::iter::once(c.len()))
                    .max()
                    .unwrap()
            })
            .collect();
        println!("\n=== {} ===", self.title);
        print!("{:w0$}", "method");
        for (c, w) in self.columns.iter().zip(&widths) {
            print!(" | {c:>w$}");
        }
        println!();
        let total: usize = w0 + widths.iter().map(|w| w + 3).sum::<usize>();
        println!("{}", "-".repeat(total));
        for (name, cells) in &self.rows {
            print!("{name:w0$}");
            for (c, w) in cells.iter().zip(&widths) {
                print!(" | {c:>w$}");
            }
            println!();
        }
    }
}

/// Shape assertion helper: prints PASS/FAIL and tracks a global verdict.
pub struct Verdict {
    pub failures: Vec<String>,
}

impl Verdict {
    pub fn new() -> Self {
        Verdict { failures: Vec::new() }
    }

    pub fn check(&mut self, name: &str, ok: bool, detail: String) {
        if ok {
            println!("[shape-check] PASS {name}: {detail}");
        } else {
            println!("[shape-check] FAIL {name}: {detail}");
            self.failures.push(name.to_string());
        }
    }

    /// Exit the bench process with the verdict.
    pub fn finish(self) -> ! {
        if self.failures.is_empty() {
            println!("\nall shape checks passed");
            std::process::exit(0)
        } else {
            println!("\nFAILED shape checks: {:?}", self.failures);
            std::process::exit(1)
        }
    }
}

/// Wall-clock helper.
pub fn timed<T>(label: &str, f: impl FnOnce() -> T) -> T {
    let t0 = std::time::Instant::now();
    let out = f();
    println!("[timing] {label}: {:.1}s", t0.elapsed().as_secs_f64());
    out
}

/// Machine-readable bench results: every timed section lands in
/// `BENCH_<bench>.json` at the repo root as `{ms_per_op, melems_per_s?}`
/// keyed by section name, plus free-form top-level metrics.  The file
/// doubles as the committed perf baseline the next run compares against
/// (via [`BenchJson::baseline`]); `calibrated` marks whether the numbers
/// came from a full-scale run on a quiet host (`FEEDSIGN_BENCH_SCALE >=
/// 1`) — uncalibrated baselines (CI smoke runs, hand-seeded estimates)
/// are reported but never hard-gate a regression.
pub struct BenchJson {
    bench: String,
    top: BTreeMap<String, Json>,
    sections: BTreeMap<String, Json>,
}

impl BenchJson {
    pub fn new(bench: &str) -> Self {
        let mut top = BTreeMap::new();
        top.insert("bench".to_string(), Json::Str(bench.to_string()));
        top.insert("scale".to_string(), Json::Num(scale()));
        top.insert("calibrated".to_string(), Json::Bool(scale() >= 1.0));
        // attribution stamp: git rev, host threads, SIMD width, shard
        // count — lets a bench trajectory be compared across PRs/hosts
        top.insert("meta".to_string(), Json::Obj(feedsign::util::bench::run_metadata()));
        BenchJson { bench: bench.to_string(), top, sections: BTreeMap::new() }
    }

    pub fn path(bench: &str) -> String {
        format!("BENCH_{bench}.json")
    }

    /// Record one timed section: ms/op plus optional element throughput.
    pub fn section(&mut self, name: &str, ms_per_op: f64, melems_per_s: Option<f64>) {
        let mut m = BTreeMap::new();
        m.insert("ms_per_op".to_string(), Json::Num(ms_per_op));
        if let Some(t) = melems_per_s {
            m.insert("melems_per_s".to_string(), Json::Num(t));
        }
        self.sections.insert(name.to_string(), Json::Obj(m));
    }

    /// Record a free-form top-level metric (speedup factors, counters).
    pub fn metric(&mut self, name: &str, value: f64) {
        self.top.insert(name.to_string(), Json::Num(value));
    }

    pub fn note(&mut self, name: &str, value: &str) {
        self.top.insert(name.to_string(), Json::Str(value.to_string()));
    }

    /// The committed baseline for `bench`, if one exists and parses.
    /// Call *before* [`BenchJson::write`] overwrites it.
    pub fn baseline(bench: &str) -> Option<Json> {
        let text = std::fs::read_to_string(Self::path(bench)).ok()?;
        Json::parse(&text).ok()
    }

    /// ms/op a baseline recorded for `section`, if present.
    pub fn baseline_ms(base: &Json, section: &str) -> Option<f64> {
        base.get("sections")?.get(section)?.get("ms_per_op")?.as_f64()
    }

    /// Whether a baseline's numbers came from a full-scale run — only
    /// calibrated baselines arm the hard regression gate.  Delegates to
    /// the library-resident predicate (`util::bench`) so the
    /// uncalibrated path stays covered by `cargo test`, which never runs
    /// the `harness = false` bench binaries.
    pub fn baseline_calibrated(base: &Json) -> bool {
        feedsign::util::bench::baseline_calibrated(base)
    }

    /// Whether the hard no-regression gate should arm for this run
    /// (calibrated baseline AND full-scale current run); see
    /// `util::bench::regression_gate_armed`.
    pub fn gate_armed(base: &Json) -> bool {
        feedsign::util::bench::regression_gate_armed(base, scale())
    }

    /// Serialize and write `BENCH_<bench>.json`, consuming the recorder.
    pub fn write(mut self) {
        self.top.insert("sections".to_string(), Json::Obj(std::mem::take(&mut self.sections)));
        let path = Self::path(&self.bench);
        let mut text = Json::Obj(self.top).to_string_compact();
        text.push('\n');
        std::fs::write(&path, text).expect("write bench json");
        println!("[bench-json] wrote {path}");
    }
}
