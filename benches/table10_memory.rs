//! Table 10 reproduction: client memory footprint of the three training
//! modes (paper: OPT-1.3B on MultiRC — inference 4.0 GB, inference +
//! optimizer 10.2 GB, backprop 46.6 GB).
//!
//! Measured on the native substrate via live-buffer accounting: parameters
//! + activation scratch (inference / ZO probe), + optimizer moments
//! (Adam-style approach 1), + per-layer gradient buffers and the dense
//! gradient (backprop).  Shape assertion: ZO probe memory ≪ backprop
//! memory, and the ratio grows with the FO:ZO structure the paper reports
//! (~1:11.6 at OPT-1.3B; smaller here because our model is tiny and the
//! batch dominates less).

mod common;

use common::*;
use feedsign::data::{corpus, Dataset};
use feedsign::simkit::nn::{Model, ModelCfg, TransformerSim};

fn measure(cfg: &ModelCfg, batch_rows: usize) -> (usize, usize, usize, usize) {
    let mut model = TransformerSim::new(cfg.clone());
    let w = model.init(0);
    let d = corpus::generate(&corpus::GrammarSpec::default(), cfg.vocab, cfg.seq_len, batch_rows, 0);
    let batch = Dataset::gather(&d, &(0..batch_rows).collect::<Vec<_>>());

    let param_bytes = w.len() * 4;

    // inference / ZO probe: activations + one perturbed parameter view
    model.loss(&w, &batch);
    let act_bytes = model.activation_bytes();
    let zo_bytes = param_bytes /* perturbed view */ + act_bytes;

    // "approach 1": ZO + Adam-style optimizer state (2 moments)
    let zo_opt_bytes = zo_bytes + 2 * param_bytes;

    // backprop: activations + dense gradient + transient per-layer grad
    // buffers (dqkv + dmerged + dmlp buffers ~ activations again)
    let mut grad = vec![0.0f32; w.len()];
    model.loss_and_grad(&w, &batch, &mut grad);
    let bp_bytes = model.activation_bytes() * 2 + grad.len() * 4;

    (param_bytes, zo_bytes, zo_opt_bytes, bp_bytes)
}

fn main() {
    let mut table = Table::new(
        "Table 10: client memory beyond the model weights (measured, bytes)",
        &["params", "ZO probe (Approach 2)", "ZO + optimizer (Approach 1)", "FO backprop"],
    );
    let mut v = Verdict::new();
    for (name, cfg, rows) in [
        ("lm-bench", ModelCfg::new(48, 16, 1, 2, 12), 8usize),
        ("lm-small", ModelCfg::new(64, 32, 2, 4, 16), 8),
        ("lm-medium", ModelCfg::new(256, 64, 4, 4, 32), 8),
    ] {
        let (p, zo, zo_opt, bp) = measure(&cfg, rows);
        table.row(
            name,
            vec![
                format!("{p}"),
                format!("{zo}"),
                format!("{zo_opt}"),
                format!("{bp}"),
            ],
        );
        v.check(
            &format!("{name}-zo-below-backprop"),
            zo < bp,
            format!("zo {zo} vs bp {bp} ({:.1}x)", bp as f64 / zo as f64),
        );
        v.check(
            &format!("{name}-ordering"),
            zo <= zo_opt && zo_opt <= bp + 2 * p,
            format!("{zo} <= {zo_opt} <= {bp}+2p"),
        );
    }
    table.print();
    println!("(paper Table 10, OPT-1.3B: 4027 MB / 10222 MB / 46583 MB — same ordering)");
    println!("note: at paper scale activations dwarf the probe view, pushing the FO:ZO ratio to ~11.6x;");
    println!("      our models are small enough that parameters dominate, so the ratio is smaller but the ordering is identical.");

    // coordinator-side counterpart: the client memory story above is
    // per-device; the session coordinator used to pay K dense replicas
    // on top of it.  The copy-on-write replica plane
    // (`coordinator::replica`) collapses an all-synced pool to one
    // canonical d-float buffer, flat in K.
    let mut coord = Table::new(
        "Coordinator replica memory (FeedSign, 10 rounds, measured bytes)",
        &["dense K*d", "cow peak", "ratio", "spill resident"],
    );
    // tiered canonical store: a 4-page window of 64-float tiles (1 KiB)
    // forces the 1290-float quickstart canonical out of core every round
    let spill_tile = 64usize;
    let spill_budget = 4 * spill_tile * 4;
    for k in [5usize, 25, 200] {
        let mut cfg = feedsign::config::quickstart();
        cfg.clients = k;
        cfg.rounds = 10;
        cfg.eval_every = 0;
        cfg.verbose = false;
        let mut s = cfg.build_session().expect("config builds");
        for t in 0..10 {
            s.step(t);
        }
        let st = s.replica_stats();
        // the same run with the canonical store spilling to disk: the
        // resident window must hold to the byte budget (flat in d) while
        // the model stream stays bit-identical to the in-RAM run
        let mut scfg = cfg.clone();
        scfg.tile = spill_tile;
        scfg.tile_budget = spill_budget;
        let mut sp = scfg.build_session().expect("config builds");
        for t in 0..10 {
            sp.step(t);
        }
        let ts = sp.replica_stats().tile;
        let bits_match = sp
            .replicas
            .canonical()
            .iter()
            .zip(s.replicas.canonical())
            .all(|(a, b)| a.to_bits() == b.to_bits());
        coord.row(
            &format!("K={k}"),
            vec![
                format!("{}", st.dense_bytes),
                format!("{}", st.peak_bytes),
                format!("{:.0}x", st.dense_bytes as f64 / st.peak_bytes.max(1) as f64),
                format!("{} (<= {})", ts.peak_resident_bytes, spill_budget),
            ],
        );
        v.check(
            &format!("coordinator-k{k}-cow-peak-is-o-d"),
            st.peak_bytes <= 2 * 4 * st.d && st.owned_clients == 0,
            format!(
                "peak {} B vs 2·d = {} B (dense would be {} B)",
                st.peak_bytes,
                2 * 4 * st.d,
                st.dense_bytes
            ),
        );
        v.check(
            &format!("coordinator-k{k}-spill-flat-memory"),
            ts.peak_resident_bytes <= spill_budget && ts.spills > 0 && bits_match,
            format!(
                "peak resident {} B <= budget {spill_budget} B ({} spills, {} fetches), \
                 bitwise match with in-RAM run: {bits_match}",
                ts.peak_resident_bytes, ts.spills, ts.fetches
            ),
        );
    }
    coord.print();
    v.finish()
}
