//! Figure 5/6 + §D.1 reproduction: orbit-based model storage and sharing.
//!
//! Regenerates the storage comparison — dense checkpoint bytes vs orbit
//! bytes as a function of fine-tuning steps and model size — including the
//! paper's headline cell: a 10,000-step FeedSign fine-tune of OPT-13B
//! stored in ~1.3 KB (bit-packed signs) against a 24 GB dense delta.
//! Also measures replay cost (the "fortuitous late-joining client"
//! scenario of §D.2) and verifies bit-exactness.

mod common;

use common::*;
use feedsign::orbit::{decode, encode, storage_report, Orbit};
use feedsign::simkit::prng::{normals_vec, Rng};
use feedsign::simkit::zo;

fn main() {
    let mut v = Verdict::new();

    // storage scaling table: steps x model size
    let steps_grid = [1000usize, 10_000, 100_000];
    let model_sizes: [(&str, usize); 4] = [
        ("0.12M (tiny)", 118_784),
        ("12.5M (base)", 12_535_808),
        ("1.3B (OPT-1.3B)", 1_300_000_000),
        ("13B (OPT-13B)", 13_000_000_000 / 4 * 4),
    ];
    let mut table = Table::new(
        "Fig 5/6: orbit bytes vs dense checkpoint bytes",
        &["steps", "orbit B", "ckpt B", "ratio"],
    );
    let mut rng = Rng::new(1, 0);
    for (name, n_params) in model_sizes {
        for steps in steps_grid {
            let mut orbit = Orbit::new("feedsign", 0, 1e-3);
            for _ in 0..steps {
                orbit.push_sign(if rng.uniform() < 0.5 { 1 } else { -1 });
            }
            let rep = storage_report(&orbit, n_params);
            table.row(
                name,
                vec![
                    format!("{steps}"),
                    format!("{}", rep.orbit_bytes),
                    format!("{}", rep.checkpoint_bytes),
                    format!("{:.1e}", rep.ratio),
                ],
            );
        }
    }
    table.print();

    // the paper's headline cell
    let mut orbit = Orbit::new("feedsign", 0, 1e-3);
    for t in 0..10_000 {
        orbit.push_sign(if t % 3 == 0 { -1 } else { 1 });
    }
    let rep13b = storage_report(&orbit, 13_000_000_000 / 4 * 4);
    println!(
        "\nOPT-13B, 10k steps: orbit {} B vs checkpoint {:.0} GB — {:.1e}x smaller",
        rep13b.orbit_bytes,
        rep13b.checkpoint_bytes as f64 / 1e9,
        rep13b.ratio
    );
    v.check(
        "13b-orbit-under-1.5kb",
        rep13b.orbit_bytes < 1500,
        format!("{} bytes (paper: <200 B information-theoretic, 1250 B bit-packed)", rep13b.orbit_bytes),
    );

    // roundtrip + replay timing at a real size (the late-joiner scenario)
    let n = 118_784usize;
    let w0 = normals_vec(3, n);
    let mut w = w0.clone();
    for t in 0..2000u32 {
        let feedsign::orbit::OrbitEntry::Sign(s) = orbit.entries[t as usize] else { unreachable!() };
        zo::apply_update(&mut w, t, s as f32 * 1e-3);
    }
    let mut orbit2k = Orbit::new("feedsign", 0, 1e-3);
    orbit2k.entries = orbit.entries[..2000].to_vec();
    let bytes = encode(&orbit2k);
    let back = decode(&bytes).expect("roundtrip");
    let t0 = std::time::Instant::now();
    let mut w_replay = w0;
    back.replay(&mut w_replay);
    let replay_s = t0.elapsed().as_secs_f64();
    v.check("replay-bit-exact", w_replay == w, "replayed == trained".into());
    println!(
        "late-joiner catch-up: replayed 2000 steps x {n} params in {replay_s:.2}s ({:.1} Msteps-params/s)",
        2000.0 * n as f64 / replay_s / 1e6
    );
    v.check("replay-fast-enough", replay_s < 30.0, format!("{replay_s:.2}s"));
    v.finish()
}
