//! Table 2 / Table 7 reproduction: main results over language tasks.
//!
//! Paper: OPT-13B (Table 2) / RoBERTa-large few-shot (Table 7) across the
//! task columns, methods = zero-shot, FO(FedSGD), MeZO, ZO-FedSGD,
//! FeedSign.  Substituted workload: the synth task suite on the bench LM
//! (DESIGN.md §4) — absolute numbers differ, the *shape* must hold:
//!
//! 1. every fine-tuning method beats zero-shot on average;
//! 2. FO is the upper bound on average;
//! 3. FeedSign lands within a few points of ZO-FedSGD (paper: FeedSign
//!    slightly ahead on most tasks) — we assert |gap| is small relative
//!    to the FO−zero-shot span;
//! 4. FeedSign uses 1/64 the uplink of ZO-FedSGD at equal steps.
//!
//! Usage: `cargo bench --bench table2_language_tasks` (env
//! `FEEDSIGN_BENCH_SCALE` scales budgets, `FEEDSIGN_TABLE7=1` switches to
//! the few-shot column set).

mod common;

use common::*;
use feedsign::config::ExperimentConfig;
use feedsign::data::tasks;

fn cfg(task: &str, algorithm: &str, rounds: u64, eta: f32) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table2-{task}-{algorithm}"),
        model: bench_lm(),
        task: lm_task(task),
        algorithm: algorithm.into(),
        clients: if algorithm == "mezo" { 1 } else { 5 },
        rounds,
        eta,
        mu: 1e-3,
        batch_size: 8,
        eval_every: (rounds / 4).max(1),
        eval_batches: 4,
        eval_batch_size: 32,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 300,
        seed: 11,
        verbose: false,
    }
}

fn main() {
    let few_shot = std::env::var("FEEDSIGN_TABLE7").is_ok();
    let task_list: Vec<&str> = if few_shot {
        tasks::ROBERTA_TASKS.iter().map(|t| t.name).collect()
    } else {
        tasks::OPT_TASKS.iter().map(|t| t.name).collect()
    };
    let title = if few_shot {
        "Table 7: few-shot language tasks (synth substitute)"
    } else {
        "Table 2: main language-task results (synth substitute)"
    };

    // budgets: ZO methods get the full budget, FO converges in far fewer
    // steps (the paper equalises *perturbations*, we equalise to wall-clock
    // sanity); eta per method follows Table 11's ZO/FO split.
    let zo_rounds = scaled(1500);
    let fo_rounds = scaled(150);
    let n = repeats();

    let methods: [(&str, u64, f32); 4] = [
        ("fedsgd", fo_rounds, 0.2),
        ("mezo", zo_rounds, 3e-3),
        ("zo-fedsgd", zo_rounds, 3e-3),
        ("feedsign", zo_rounds, 3e-3),
    ];

    let mut table = Table::new(title, &task_list.iter().map(|t| &t[6..]).collect::<Vec<_>>());
    let mut grid: Vec<(String, Vec<f32>)> = Vec::new();

    // zero-shot row
    let zs: Vec<f32> = task_list.iter().map(|t| zero_shot(&cfg(t, "feedsign", 10, 1e-3))).collect();
    table.row("zero-shot", zs.iter().map(|a| format!("{a:.1}")).collect());
    grid.push(("zero-shot".into(), zs));

    let mut up_bits = std::collections::BTreeMap::new();
    for (algo, rounds, eta) in methods {
        let mut means = Vec::new();
        let mut cells = Vec::new();
        for task in &task_list {
            let c = cfg(task, algo, rounds, eta);
            let runs = run_repeats(&c, n);
            let ms = best_accs(&runs);
            up_bits.insert(algo.to_string(), runs[0].ledger.uplink_bits);
            means.push(ms.mean);
            cells.push(format!("{ms}"));
        }
        table.row(algo, cells);
        grid.push((algo.to_string(), means));
    }
    table.print();

    // per-method averages + gap column (paper's rightmost "Gap")
    let avg = |name: &str| -> f32 {
        let row = &grid.iter().find(|(n, _)| n == name).unwrap().1;
        row.iter().sum::<f32>() / row.len() as f32
    };
    let (a_zs, a_fo) = (avg("zero-shot"), avg("fedsgd"));
    let (a_mezo, a_zo, a_fs) = (avg("mezo"), avg("zo-fedsgd"), avg("feedsign"));
    println!(
        "\naverages: zero-shot {a_zs:.1} | FO {a_fo:.1} | MeZO {a_mezo:.1} | ZO-FedSGD {a_zo:.1} | FeedSign {a_fs:.1}"
    );
    println!(
        "gap to FO: MeZO {:+.1} | ZO-FedSGD {:+.1} | FeedSign {:+.1} (paper: -3.1 / -7.6 / -6.4)",
        a_mezo - a_fo,
        a_zo - a_fo,
        a_fs - a_fo
    );

    let mut v = Verdict::new();
    v.check("ft-beats-zero-shot", a_fs > a_zs + 3.0 && a_zo > a_zs + 3.0,
        format!("feedsign {a_fs:.1}, zo-fedsgd {a_zo:.1} vs zero-shot {a_zs:.1}"));
    v.check("fo-upper-bound", a_fo >= a_fs - 2.0 && a_fo >= a_zo - 2.0,
        format!("fo {a_fo:.1} vs zo methods {a_fs:.1}/{a_zo:.1}"));
    let span = (a_fo - a_zs).max(1.0);
    v.check("feedsign-close-to-zo-fedsgd", (a_fs - a_zo).abs() <= 0.35 * span,
        format!("|{a_fs:.1} - {a_zo:.1}| vs span {span:.1}"));
    let (up_fs, up_zo) = (up_bits["feedsign"], up_bits["zo-fedsgd"]);
    v.check("comm-1-over-64", up_zo == 64 * up_fs,
        format!("uplink zo-fedsgd {up_zo} vs feedsign {up_fs} bits"));
    v.finish()
}
