//! Figure 3 (and Figure 4) reproduction: loss/accuracy curves vs steps
//! under BK = 0..3 Byzantine attackers, K = 25, vision FFT.
//!
//! Paper: ZO-FedSGD is progressively compromised as BK grows; FeedSign's
//! convergence is not compromised until BK = 3.  Emits all 8 curve series
//! (CSV) and asserts: (a) with BK = 0 the two methods are comparable;
//! (b) at BK = 3 FeedSign's final accuracy exceeds ZO-FedSGD's;
//! (c) FeedSign's BK=3 degradation vs BK=0 is smaller than ZO-FedSGD's.

mod common;

use common::*;
use feedsign::config::ExperimentConfig;

fn cfg(algorithm: &str, byzantine: usize, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("fig3-{algorithm}-bk{byzantine}"),
        model: vision_model("synth-cifar10"),
        task: vision_task("synth-cifar10"),
        algorithm: algorithm.into(),
        clients: 25,
        rounds,
        eta: 1e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: (rounds / 16).max(1),
        eval_batches: 6,
        eval_batch_size: 64,
        dirichlet_beta: None,
        byzantine_count: byzantine,
        attack: Some(if algorithm == "feedsign" {
            "sign-flip".into()
        } else {
            "random-projection:20.0".into()
        }),
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 41,
        verbose: false,
    }
}

fn main() {
    let rounds = scaled(8000);
    let mut acc = std::collections::BTreeMap::new();
    for algo in ["zo-fedsgd", "feedsign"] {
        for bk in 0..=3usize {
            let c = cfg(algo, bk, rounds);
            let mut session = c.build_session().expect("builds");
            let result = timed(&format!("{algo} BK={bk}"), || session.run());
            let path = format!("target/fig3_{algo}_bk{bk}.csv");
            let _ = std::fs::write(&path, result.to_csv());
            println!(
                "  final: loss {:.4} acc {:.1}% (curve -> {path})",
                result.final_loss,
                result.final_acc * 100.0
            );
            acc.insert((algo.to_string(), bk), result.final_acc * 100.0);
        }
    }

    println!("\n== Fig 3 summary: final accuracy (%) by attacker count ==");
    println!("{:>12} | {:>6} | {:>6} | {:>6} | {:>6}", "method", "BK=0", "BK=1", "BK=2", "BK=3");
    for algo in ["zo-fedsgd", "feedsign"] {
        println!(
            "{algo:>12} | {:>6.1} | {:>6.1} | {:>6.1} | {:>6.1}",
            acc[&(algo.to_string(), 0)],
            acc[&(algo.to_string(), 1)],
            acc[&(algo.to_string(), 2)],
            acc[&(algo.to_string(), 3)]
        );
    }
    println!("(paper Fig 3: ZO-FedSGD degrades with each attacker; FeedSign holds to BK=3)");

    let mut v = Verdict::new();
    let fs0 = acc[&("feedsign".to_string(), 0)];
    let fs3 = acc[&("feedsign".to_string(), 3)];
    let zo0 = acc[&("zo-fedsgd".to_string(), 0)];
    let zo3 = acc[&("zo-fedsgd".to_string(), 3)];
    v.check("clean-comparable", (fs0 - zo0).abs() < 15.0, format!("{fs0:.1} vs {zo0:.1}"));
    v.check("feedsign-wins-at-bk3", fs3 > zo3, format!("{fs3:.1} vs {zo3:.1}"));
    v.check(
        "feedsign-degrades-less",
        (fs0 - fs3) < (zo0 - zo3),
        format!("feedsign -{:.1} vs zo -{:.1}", fs0 - fs3, zo0 - zo3),
    );
    v.finish()
}
