//! Table 9 / Figure 4 reproduction: vision FFT with one Byzantine client
//! of K = 5.
//!
//! Paper (ViT-large): ZO-FedSGD is *completely compromised* (CIFAR-100
//! drops to 10.9) while FeedSign keeps its clean accuracy (91.9 / 40.8).
//! Shape assertions: (a) FeedSign attacked ≈ FeedSign clean;
//! (b) ZO-FedSGD attacked drops by a large margin, far more than
//!     FeedSign's drop.

mod common;

use common::*;
use feedsign::config::ExperimentConfig;

fn cfg(task: &str, algorithm: &str, byzantine: usize, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table9-{task}-{algorithm}-{byzantine}"),
        model: vision_model(task),
        task: vision_task(task),
        algorithm: algorithm.into(),
        clients: 5,
        rounds,
        // calibrated per-algorithm (FeedSign's fixed step prefers a smaller
        // eta; ZO-FedSGD scales steps by |p| so it tolerates a larger one)
        eta: if algorithm == "feedsign" { 1e-3 } else { 2e-3 },
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        eval_batches: 8,
        eval_batch_size: 64,
        dirichlet_beta: None,
        byzantine_count: byzantine,
        // the strongest attacker per protocol (Remark 3.14): huge random
        // projections poison ZO-FedSGD's mean; sign flips are all a
        // FeedSign attacker has
        attack: Some(if algorithm == "feedsign" {
            "sign-flip".into()
        } else {
            "random-projection:20.0".into()
        }),
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 31,
        verbose: false,
    }
}

fn main() {
    let r10 = scaled(8000);
    let r100 = scaled(16_000);
    let n = repeats();

    let mut table = Table::new(
        "Table 9: vision FFT with 1 Byzantine of K=5 (synth substitute)",
        &["synth-cifar10", "synth-cifar100"],
    );
    let mut acc = std::collections::BTreeMap::new();
    for (label, algo, byz) in [
        ("zo-fedsgd clean", "zo-fedsgd", 0usize),
        ("zo-fedsgd +1byz", "zo-fedsgd", 1),
        ("feedsign clean", "feedsign", 0),
        ("feedsign +1byz", "feedsign", 1),
    ] {
        let mut cells = Vec::new();
        for (task, rounds) in [("synth-cifar10", r10), ("synth-cifar100", r100)] {
            let runs = run_repeats(&cfg(task, algo, byz, rounds), n);
            let ms = best_accs(&runs);
            acc.insert((label, task), ms.mean);
            cells.push(format!("{ms}"));
        }
        table.row(label, cells);
    }
    table.print();
    println!("(paper Table 9: ZO-FedSGD 83.9/10.9 vs FeedSign 91.9/40.8 under attack)");

    let mut v = Verdict::new();
    let fs_drop = acc[&("feedsign clean", "synth-cifar10")] - acc[&("feedsign +1byz", "synth-cifar10")];
    let zo_drop = acc[&("zo-fedsgd clean", "synth-cifar10")] - acc[&("zo-fedsgd +1byz", "synth-cifar10")];
    // at truncated budgets a 1/5 sign-flip slows (not stops) convergence,
    // so the snapshot drop is larger than the converged drop the paper shows
    let drop_cap = if scale() >= 1.0 { 8.0 } else { 20.0 };
    v.check("feedsign-unmoved", fs_drop < drop_cap, format!("feedsign drop {fs_drop:.1} pts (cap {drop_cap})"));
    v.check(
        "zo-compromised-more",
        zo_drop > fs_drop + 3.0,
        format!("zo drop {zo_drop:.1} vs feedsign drop {fs_drop:.1}"),
    );
    v.check(
        "feedsign-beats-zo-attacked",
        acc[&("feedsign +1byz", "synth-cifar10")] > acc[&("zo-fedsgd +1byz", "synth-cifar10")],
        format!(
            "{:.1} vs {:.1}",
            acc[&("feedsign +1byz", "synth-cifar10")],
            acc[&("zo-fedsgd +1byz", "synth-cifar10")]
        ),
    );
    v.finish()
}
