//! Table 4 reproduction: language tasks under non-iid data
//! (Dirichlet beta = 1.0), FeedSign vs ZO-FedSGD vs MeZO.
//!
//! Paper (OPT-125M): both federated ZO methods drop under heterogeneity,
//! and FeedSign matches or beats ZO-FedSGD on most entries (its error
//! floor is heterogeneity-independent, Remark 3.13).  Shape assertions:
//! (a) heterogeneity costs accuracy vs the iid run for ZO-FedSGD;
//! (b) FeedSign's average is >= ZO-FedSGD's average under skew (within
//!     noise).

mod common;

use common::*;
use feedsign::config::ExperimentConfig;

const TASKS: [&str; 7] =
    ["synth-sst2", "synth-rte", "synth-cb", "synth-boolq", "synth-wsc", "synth-wic", "synth-multirc"];

fn cfg(task: &str, algorithm: &str, beta: Option<f32>, rounds: u64) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table4-{task}-{algorithm}"),
        model: bench_lm(),
        task: lm_task(task),
        algorithm: algorithm.into(),
        clients: if algorithm == "mezo" { 1 } else { 5 },
        rounds,
        eta: 3e-3,
        mu: 1e-3,
        batch_size: 8,
        eval_every: (rounds / 4).max(1),
        eval_batches: 4,
        eval_batch_size: 32,
        dirichlet_beta: beta,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 300,
        seed: 17,
        verbose: false,
    }
}

fn main() {
    let rounds = scaled(1500);
    let n = repeats();
    let mut table = Table::new(
        "Table 4: non-iid language tasks, Dirichlet beta=1.0 (synth substitute)",
        &TASKS.iter().map(|t| &t[6..]).collect::<Vec<_>>(),
    );

    let mut avg = std::collections::BTreeMap::new();
    let rows: [(&str, &str, Option<f32>); 4] = [
        ("mezo (centralized)", "mezo", None),
        ("zo-fedsgd iid", "zo-fedsgd", None),
        ("zo-fedsgd b=1.0", "zo-fedsgd", Some(1.0)),
        ("feedsign b=1.0", "feedsign", Some(1.0)),
    ];
    for (label, algo, beta) in rows {
        let mut cells = Vec::new();
        let mut means = Vec::new();
        for task in TASKS {
            let runs = run_repeats(&cfg(task, algo, beta, rounds), n);
            let ms = best_accs(&runs);
            means.push(ms.mean);
            cells.push(format!("{ms}"));
        }
        avg.insert(label, means.iter().sum::<f32>() / means.len() as f32);
        table.row(label, cells);
    }
    table.print();
    println!("\naverages: {avg:?}");
    println!("(paper Table 4: FeedSign >= ZO-FedSGD on most non-iid entries)");

    let mut v = Verdict::new();
    let zo_iid = avg["zo-fedsgd iid"];
    let zo_het = avg["zo-fedsgd b=1.0"];
    let fs_het = avg["feedsign b=1.0"];
    v.check(
        "heterogeneity-hurts-zo",
        zo_het <= zo_iid + 1.0,
        format!("zo-fedsgd {zo_iid:.1} (iid) vs {zo_het:.1} (b=1.0)"),
    );
    v.check(
        "feedsign-holds-under-skew",
        fs_het >= zo_het - 2.0,
        format!("feedsign {fs_het:.1} vs zo-fedsgd {zo_het:.1} under skew"),
    );
    v.finish()
}
