//! Table 8 reproduction: effect of client-pool size (K = 5 vs 25) at a
//! fixed perturbation budget — plus the partial-participation regime the
//! coordinator's `participation` knob now expresses directly.
//!
//! Paper (OPT-125M, iid): with the number of perturbations held constant
//! (K=25 runs 1/5 the rounds of K=5, Table 12), both methods stay in the
//! same accuracy band; bigger pools buy fewer, better-averaged steps.
//! The `fraction:0.2` row runs the *same* 25-client pool but samples ~5
//! participants per round (`coordinator::participation`) — the realistic
//! deployment regime, with the perturbation budget matched to K=5 — and
//! must land in the same band too.
//!
//! Shape assertions: (a) every federated cell beats zero-shot;
//! (b) at matched perturbations, |K=5 - K=25| is modest for FeedSign
//! (vote averaging) — within 12 points on average; (c) partial
//! participation of the big pool stays within the same band of K=5.
//!
//! The run also reports a **replay-cost column**: total downlink for
//! `catchup = off | replay | rebroadcast` on the fraction:0.2 pool.
//! Replay must bill exactly the broadcast-to-everyone baseline's bits
//! (each (client, round) pair billed once, live or replayed) while a
//! dense rebroadcast pays 32·d per rejoin — the FedKSeed-style byproduct
//! `coordinator::catchup` exists to capture.
//!
//! Finally, a **straggler/deadline scenario** runs the same pool over
//! heterogeneous `net` link profiles with a round deadline: iot-class
//! clients are cut at plan time and resync through replay, and the run
//! must not collapse.
//!
//! A **seed-pool ledger-storage column** (FedKSeed's restricted seed
//! space, `seed_pool = 4096`) shows each committed round costing
//! `ceil(log2 K) + 1 = 13` bits in the packed-index orbit — at least 4x
//! below a dense (seed, scalar) ledger entry.
//!
//! A **sharded-coordinator scale scenario** (`coordinator::shard`,
//! `--shards N`) pushes the pool to K in {10^4, 10^5}: coordinator
//! memory must stay flat in K (the shards share one canonical buffer
//! read-only) and round throughput must scale near-linearly in the
//! shard count — recorded in `BENCH_table8_shards.json`, runnable alone
//! via `FEEDSIGN_TABLE8_SHARDS_ONLY=1`.

mod common;

use common::*;
use feedsign::config::{ExperimentConfig, TaskSpec};
use feedsign::coordinator::ParticipationCfg;

const TASKS: [&str; 4] = ["synth-sst2", "synth-cb", "synth-copa", "synth-boolq"];

fn cfg(
    task: &str,
    algorithm: &str,
    k: usize,
    rounds: u64,
    participation: &str,
    catchup: &str,
) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table8-{task}-{algorithm}-k{k}-{participation}-{catchup}"),
        model: bench_lm(),
        task: lm_task(task),
        algorithm: algorithm.into(),
        clients: k,
        rounds,
        eta: 3e-3,
        mu: 1e-3,
        batch_size: 8,
        eval_every: (rounds / 4).max(1),
        eval_batches: 4,
        eval_batch_size: 32,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: participation.into(),
        catchup: catchup.into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 300,
        seed: 29,
        verbose: false,
    }
}

/// Config for the sharded-coordinator scale scenario: a vision-probe
/// pool of `k` clients with ~1000 voters per round, the round engine
/// pinned to `shards` coordinator shards over `threads` workers.
fn shard_cfg(k: usize, rounds: u64, shards: usize, threads: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("table8-shards-k{k}-n{shards}"),
        model: vision_model("synth-cifar10"),
        // one sample per client floor: `split` requires n >= K
        task: TaskSpec::SynthVision { name: "synth-cifar10".into(), train: k.max(2000), test: 200 },
        algorithm: "feedsign".into(),
        clients: k,
        rounds,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 8,
        eval_every: 0,
        eval_batches: 2,
        eval_batch_size: 32,
        dirichlet_beta: None,
        byzantine_count: 0,
        attack: None,
        c_g_noise: 0.0,
        participation: format!("fraction:{}", 1000.0 / k as f64),
        catchup: "off".into(),
        seed_pool: 0,
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads,
        replica_cache: 4,
        shards,
        pretrain_rounds: 0,
        seed: 29,
        verbose: false,
    }
}

/// The K >= 10^4 regime the sharded coordinator unlocks (ROADMAP item 1):
/// pools at K in {10_000, 100_000} with ~1000 voters per round.  Two
/// claims, recorded in `BENCH_table8_shards.json`:
///
/// * **memory flat in K** — the replica plane holds <= 2·d floats
///   whatever K (hard check at both pool sizes; sharding shares the one
///   canonical buffer read-only, so the shard count does not multiply
///   it);
/// * **round throughput near-linear in shards** — stepping rate at
///   N = 4 shards (4 workers) vs the 1-shard sequential engine must
///   reach >= 0.7·N.  Hard-gated only on calibrated full-scale runs
///   (`FEEDSIGN_BENCH_SCALE >= 1` on a quiet >= 4-core host); smoke
///   runs report it advisorily.
///
/// Runs standalone in the CI perf-smoke job via
/// `FEEDSIGN_TABLE8_SHARDS_ONLY=1`.
fn shard_scale_scenario(v: &mut Verdict) {
    let rounds = scaled(20);
    let mut bj = BenchJson::new("table8_shards");
    bj.metric("rounds", rounds as f64);
    for &k in &[10_000usize, 100_000] {
        // sequential single-shard baseline vs 4 shards over 4 workers
        let mut rates = Vec::new();
        for &(shards, threads) in &[(1usize, 1usize), (4, 4)] {
            let c = shard_cfg(k, rounds, shards, threads);
            let mut s = c.build_session().expect("config builds");
            let t0 = std::time::Instant::now();
            for t in 0..rounds {
                s.step(t);
            }
            let dt = t0.elapsed().as_secs_f64().max(1e-9);
            let rate = rounds as f64 / dt;
            rates.push(rate);
            let (rs, ss) = (s.replica_stats(), s.shard_stats());
            println!(
                "shard scale K={k} N={shards}: {rate:.2} rounds/s, replica peak {} B \
                 (d = {}), {} merges, {} rounds planned ahead",
                rs.peak_bytes, rs.d, ss.merges, ss.rounds_overlapped
            );
            v.check(
                &format!("shards-k{k}-n{shards}-replica-peak-flat-in-k"),
                rs.peak_bytes <= 2 * 4 * rs.d && rs.owned_clients == 0,
                format!("peak {} B vs 2·d = {} B at K = {k}", rs.peak_bytes, 2 * 4 * rs.d),
            );
            if shards > 1 {
                v.check(
                    &format!("shards-k{k}-merges-metered"),
                    ss.merges > 0 && ss.rounds_overlapped > 0,
                    format!("{} merges, {} overlapped rounds", ss.merges, ss.rounds_overlapped),
                );
            }
            bj.metric(&format!("k{k}_n{shards}_rounds_per_s"), rate);
            bj.metric(&format!("k{k}_n{shards}_replica_peak_bytes"), rs.peak_bytes as f64);
            bj.metric(&format!("k{k}_n{shards}_merge_bits"), ss.merge_bits as f64);
        }
        let speedup = rates[1] / rates[0].max(1e-9);
        bj.metric(&format!("k{k}_speedup_n4"), speedup);
        let target = 0.7 * 4.0;
        if scale() >= 1.0 {
            v.check(
                &format!("shards-k{k}-throughput-near-linear"),
                speedup >= target,
                format!("N=4 speedup {speedup:.2} vs target {target:.1}"),
            );
        } else {
            println!(
                "shard scale K={k}: N=4 speedup {speedup:.2} \
                 (target {target:.1} gates only on calibrated runs)"
            );
        }
    }
    bj.write();
}

/// The large-pool scenario the replica plane unlocks: K = 200 clients,
/// full participation.  The dense layout would hold 200 parameter
/// buffers; the copy-on-write store holds one, flat in K, and commits
/// each round with a single canonical AXPY.  Runs standalone in the CI
/// perf-smoke job via `FEEDSIGN_TABLE8_K200_ONLY=1`.
fn k200_scenario(v: &mut Verdict) {
    let rounds = scaled(60);
    let mut c = cfg(TASKS[0], "feedsign", 200, rounds, "full", "off");
    // measure the round engine, not the warm start (pretraining is a
    // K-independent one-off)
    c.pretrain_rounds = 0;
    let run = timed("K=200 pool", || run_repeats(&c, 1).remove(0));
    println!(
        "\nlarge-pool scenario (K=200, full participation, {rounds} rounds): \
         replica peak {} B vs dense {} B, {} canonical commits, {} bits up",
        run.replica.peak_bytes,
        run.replica.dense_bytes,
        run.replica.canonical_commits,
        run.ledger.uplink_bits
    );
    v.check(
        "k200-replica-peak-below-2d",
        run.replica.peak_bytes <= 2 * 4 * run.replica.d && run.replica.owned_clients == 0,
        format!(
            "peak {} B vs 2·d = {} B (dense layout: {} B)",
            run.replica.peak_bytes,
            2 * 4 * run.replica.d,
            run.replica.dense_bytes
        ),
    );
    v.check(
        "k200-one-canonical-axpy-per-round",
        run.replica.canonical_commits == rounds,
        format!("{} commits over {rounds} rounds", run.replica.canonical_commits),
    );
    v.check(
        "k200-uplink-is-one-bit-per-client",
        run.ledger.uplink_bits == rounds * 200,
        format!("{} bits over {rounds} rounds x 200 clients", run.ledger.uplink_bits),
    );
    // machine-readable record of the pool-scale claim (CI prints this)
    let mut bj = BenchJson::new("table8_client_pool");
    bj.metric("k200_rounds", rounds as f64);
    bj.metric("k200_replica_peak_bytes", run.replica.peak_bytes as f64);
    bj.metric("k200_dense_bytes", run.replica.dense_bytes as f64);
    bj.metric("k200_canonical_commits", run.replica.canonical_commits as f64);
    bj.metric("k200_probe_canonical_passes", run.probe.canonical_passes as f64);
    bj.metric("k200_probe_unbatched_passes", run.probe.unbatched_passes() as f64);
    bj.metric("k200_wall_s", run.wall_s);
    bj.write();
}

/// Ledger-storage column for the restricted seed space (FedKSeed): at
/// K = 4096 pool seeds, every committed round costs a 12-bit index + a
/// sign bit in the orbit/SeedHistory instead of the dense
/// (32-bit seed, 32-bit scalar) pair an explicit per-round ledger would
/// hold — the >= 4x byte reduction the paper's one-bit framing rides on.
/// Runs at a fixed round count (not `scaled`) so the format header stays
/// amortized even in CI smoke runs.
fn seed_pool_storage_scenario(v: &mut Verdict) {
    let rounds: u64 = 200;
    let mut c = cfg(TASKS[0], "feedsign", 5, rounds, "full", "off");
    c.pretrain_rounds = 0;
    c.seed_pool = 4096;
    let mut s = c.build_session().expect("config builds");
    for t in 0..rounds {
        s.step(t);
    }
    let orbit_bytes = feedsign::orbit::encode(&s.orbit).len() as u64;
    let dense_bytes = s.orbit.len() as u64 * 8; // (seed u32, scalar f32) per step
    let per_step_bits = orbit_bytes as f64 * 8.0 / s.orbit.len() as f64;
    println!(
        "\nseed-pool ledger storage (K=4096 pool, {rounds} rounds): \
         {orbit_bytes} B packed-index orbit vs {dense_bytes} B dense seed/scalar \
         ledger ({:.1}x smaller, {per_step_bits:.1} bits/step)",
        dense_bytes as f64 / orbit_bytes as f64
    );
    v.check(
        "seed-pool-ledger-4x-smaller",
        orbit_bytes * 4 <= dense_bytes,
        format!("{orbit_bytes} B vs dense {dense_bytes} B"),
    );
    v.check(
        "seed-pool-steps-cost-log2k-plus-one-bits",
        per_step_bits <= 15.0,
        format!("{per_step_bits:.1} bits/step vs ceil(log2 4096) + 1 = 13"),
    );
    // every round's announcement prices at ceil(log2 K) + 1 = 13 bits
    // per client on the downlink (broadcast-to-everyone regime)
    v.check(
        "seed-pool-downlink-prices-indices",
        s.ledger.downlink_bits == rounds * 5 * 13,
        format!("{} bits over {rounds} rounds x 5 clients x 13", s.ledger.downlink_bits),
    );
    let mut bj = BenchJson::new("table8_seed_pool");
    bj.metric("pool_k", 4096.0);
    bj.metric("rounds", rounds as f64);
    bj.metric("orbit_bytes", orbit_bytes as f64);
    bj.metric("dense_ledger_bytes", dense_bytes as f64);
    bj.metric("per_step_bits", per_step_bits);
    bj.write();
}

fn main() {
    // CI perf-smoke runs only the pool-scale scenarios (the full grid is
    // a long haul at any scale)
    if std::env::var("FEEDSIGN_TABLE8_K200_ONLY").as_deref() == Ok("1") {
        let mut v = Verdict::new();
        k200_scenario(&mut v);
        seed_pool_storage_scenario(&mut v);
        v.finish();
    }
    if std::env::var("FEEDSIGN_TABLE8_SHARDS_ONLY").as_deref() == Ok("1") {
        let mut v = Verdict::new();
        shard_scale_scenario(&mut v);
        v.finish();
    }
    // fixed perturbation budget: (participants per round) * rounds = const
    // (Table 12)
    let r5 = scaled(1500);
    let r25 = (r5 / 5).max(10);
    // partial-participation row: rounds derived from the sampler's own
    // expected participants so the probe budget matches the K=5 row
    let frac = ParticipationCfg::Fraction(0.2);
    let r_frac = ((5.0 * r5 as f32 / frac.expected_participants(25)) as u64).max(10);
    let n = repeats();

    let mut table = Table::new(
        "Table 8: client-pool size at fixed perturbation budget (synth substitute)",
        &TASKS.iter().map(|t| &t[6..]).collect::<Vec<_>>(),
    );
    let zs: Vec<f32> =
        TASKS.iter().map(|t| zero_shot(&cfg(t, "feedsign", 5, 10, "full", "off"))).collect();
    table.row("zero-shot", zs.iter().map(|a| format!("{a:.1}")).collect());

    let mut avg = std::collections::BTreeMap::new();
    for (label, algo, k, rounds, participation) in [
        ("zo-fedsgd K=5", "zo-fedsgd", 5, r5, "full"),
        ("zo-fedsgd K=25", "zo-fedsgd", 25, r25, "full"),
        ("feedsign K=5", "feedsign", 5, r5, "full"),
        ("feedsign K=25", "feedsign", 25, r25, "full"),
        // the participation knob: same 25-client pool, ~5 voters/round,
        // budget matched to the K=5 row
        ("feedsign K=25 frac=0.2", "feedsign", 25, r_frac, "fraction:0.2"),
    ] {
        let mut cells = Vec::new();
        let mut means = Vec::new();
        for task in TASKS {
            let runs = run_repeats(&cfg(task, algo, k, rounds, participation, "off"), n);
            let ms = best_accs(&runs);
            means.push(ms.mean);
            cells.push(format!("{ms}"));
        }
        avg.insert(label, means.iter().sum::<f32>() / means.len() as f32);
        table.row(label, cells);
    }
    table.print();
    println!("\naverages: {avg:?}");
    println!("(paper Table 8: K=5 and K=25 land in the same band at matched perturbations)");

    let zs_avg = zs.iter().sum::<f32>() / zs.len() as f32;
    let mut v = Verdict::new();
    for (label, a) in &avg {
        v.check(
            &format!("{label}-beats-zero-shot"),
            *a > zs_avg,
            format!("{a:.1} vs zero-shot {zs_avg:.1}"),
        );
    }
    let gap = (avg["feedsign K=5"] - avg["feedsign K=25"]).abs();
    v.check("feedsign-pool-size-stable", gap < 12.0, format!("|K5 - K25| = {gap:.1}"));
    let frac_gap = (avg["feedsign K=5"] - avg["feedsign K=25 frac=0.2"]).abs();
    v.check(
        "feedsign-partial-participation-stable",
        frac_gap < 12.0,
        format!("|K5 - K25@0.2| = {frac_gap:.1}"),
    );

    // replay-cost column: what does keeping stragglers current cost?  The
    // same 25-client pool at fraction:0.2, with offline clients caught up
    // by seed-history replay vs a dense-model rebroadcast (FedKSeed-style
    // byproduct), against the paper's broadcast-to-everyone baseline.
    let r_cost = scaled(200);
    let mut cost_rows = Vec::new();
    for catchup in ["off", "replay", "rebroadcast"] {
        let c = cfg(TASKS[0], "feedsign", 25, r_cost, "fraction:0.2", catchup);
        let run = run_repeats(&c, 1).remove(0);
        cost_rows.push((catchup, run.ledger.downlink_bits));
    }
    println!("\nstraggler catch-up downlink ({r_cost} rounds, K=25, fraction:0.2):");
    for (catchup, bits) in &cost_rows {
        println!("  catchup={catchup:<12} {bits:>12} bits ({:.1} kB)", *bits as f64 / 8e3);
    }
    let (off_bits, replay_bits, rebroadcast_bits) =
        (cost_rows[0].1, cost_rows[1].1, cost_rows[2].1);
    v.check(
        "replay-bills-each-pair-once",
        replay_bits == off_bits,
        format!("replay {replay_bits} vs broadcast-to-everyone {off_bits} bits"),
    );
    v.check(
        "replay-beats-dense-rebroadcast",
        replay_bits * 10 <= rebroadcast_bits,
        format!("replay {replay_bits} vs rebroadcast {rebroadcast_bits} bits"),
    );

    // straggler/deadline scenario: the same fraction:0.2 pool, now on
    // heterogeneous links (`net::LinkAssignment` mixed cycle) with a
    // round deadline — iot-class clients blow the 0.1 s budget, get cut
    // from the plan, and resync via seed-history replay.  The paper's
    // synchronous-round assumption survives because exclusion happens at
    // plan time and the catch-up machinery restores the stragglers.
    let mut scen = cfg(TASKS[0], "feedsign", 25, r_cost, "fraction:0.2", "replay");
    scen.link = "mixed".into();
    scen.deadline = 0.1;
    let run = run_repeats(&scen, 1).remove(0);
    println!(
        "\nstraggler scenario (mixed links, 0.1 s deadline, {r_cost} rounds): \
         {} exclusions, {:.1}s virtual wall-clock, {} bits down",
        run.net.stragglers, run.net.virtual_s, run.ledger.downlink_bits
    );
    v.check(
        "deadline-excludes-stragglers",
        run.net.stragglers > 0,
        format!("{} straggler exclusions", run.net.stragglers),
    );
    v.check(
        "straggler-run-does-not-collapse",
        run.best_acc() * 100.0 >= zs[0] - 5.0,
        format!("{:.1}% vs zero-shot {:.1}%", run.best_acc() * 100.0, zs[0]),
    );

    // the pool the replica plane unlocks
    k200_scenario(&mut v);
    // the ledger the restricted seed space shrinks
    seed_pool_storage_scenario(&mut v);
    // the pool size the sharded coordinator unlocks
    shard_scale_scenario(&mut v);
    v.finish()
}
