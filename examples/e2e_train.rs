//! End-to-end driver: the full three-layer stack on a real (small)
//! workload, python never on the request path.
//!
//! Pipeline (all compute through `artifacts/*.hlo.txt` via PJRT):
//!   1. **Pretrain** the transformer LM on the template-grammar corpus with
//!      the first-order `fo_step` graph (produces the "pretrained
//!      checkpoint" every FFT experiment assumes — Assumption 3.5's low
//!      effective rank comes from here);
//!   2. **Federate**: K clients FeedSign-fine-tune the checkpoint on a
//!      synthetic classification task (label tokens the corpus never
//!      produced), 1 bit up / 1 bit down per client per round, logging
//!      the loss curve and the exact comm-bit ledger;
//!   3. **Verify**: orbit replay reconstructs the fine-tuned weights
//!      bit-exactly from the checkpoint + the 1-bit/step orbit.
//!
//! Defaults are sized for a ~5 minute single-core run on the `tiny`
//! variant (0.12M params); pass `--variant small|base --pretrain N
//! --rounds N --clients K` to scale up (base = 12.5M params, the 11M end
//! of the paper's model range).  Results are recorded in EXPERIMENTS.md.

use anyhow::{Context, Result};
use feedsign::comm::{Ledger, LinkModel, Message};
use feedsign::coordinator::aggregation::majority_sign;
use feedsign::data::partition::{split, Partition};
use feedsign::data::{corpus, tasks, Shard};
use feedsign::orbit::{encode, Orbit};
use feedsign::runtime::{artifacts_dir, PjrtModel};
use feedsign::simkit::prng::Rng;

struct Flags {
    variant: String,
    pretrain: u64,
    rounds: u64,
    clients: usize,
    eta: f32,
    mu: f32,
}

fn flags() -> Flags {
    let args: Vec<String> = std::env::args().collect();
    let get = |name: &str| -> Option<String> {
        args.iter()
            .position(|a| a == &format!("--{name}"))
            .and_then(|i| args.get(i + 1).cloned())
    };
    Flags {
        variant: get("variant").unwrap_or_else(|| "tiny".into()),
        pretrain: get("pretrain").and_then(|v| v.parse().ok()).unwrap_or(120),
        rounds: get("rounds").and_then(|v| v.parse().ok()).unwrap_or(160),
        clients: get("clients").and_then(|v| v.parse().ok()).unwrap_or(3),
        eta: get("eta").and_then(|v| v.parse().ok()).unwrap_or(2e-3),
        mu: get("mu").and_then(|v| v.parse().ok()).unwrap_or(1e-3),
    }
}

fn main() -> Result<()> {
    let f = flags();
    let dir = artifacts_dir();
    println!("[e2e] loading AOT artifacts for variant {:?} from {}", f.variant, dir.display());
    let t_load = std::time::Instant::now();
    let model = PjrtModel::load(&dir, &f.variant).context("run `make artifacts` first")?;
    println!(
        "[e2e] compiled 7 step graphs on {} in {:.1}s — {} params (padded {})",
        model.platform(),
        t_load.elapsed().as_secs_f64(),
        model.entry.n_params,
        model.entry.padded_size
    );
    let (vocab, seq_len) = (model.entry.vocab, model.entry.seq_len);
    let (bp, be) = (model.entry.batch_probe, model.entry.batch_eval);

    // ---------------- Stage 1: FO pretraining on the corpus ----------------
    let grammar = corpus::GrammarSpec::default();
    let pre_train = corpus::generate(&grammar, vocab, seq_len, 2048, 1);
    let pre_eval = corpus::generate(&grammar, vocab, seq_len, 256, 2);
    let mut w = model.init_params(0);
    let mut rng = Rng::new(42, 0);
    let mut shard = Shard::new((0..pre_train.len()).collect());
    let t0 = std::time::Instant::now();
    let mut first_loss = f32::NAN;
    for step in 0..f.pretrain {
        let batch = shard.next_batch(&pre_train, bp, &mut rng);
        let loss = model.fo_step(&mut w, &batch, 0.25)?;
        if step == 0 {
            first_loss = loss;
        }
        if step % 20 == 0 || step + 1 == f.pretrain {
            println!("[pretrain] step {step:>4}: train loss {loss:.4}");
        }
    }
    let eval_batch = pre_eval.gather(&(0..be).collect::<Vec<_>>());
    let (pre_loss, _) = model.eval(&w, &eval_batch)?;
    println!(
        "[pretrain] {} FO steps in {:.1}s: loss {first_loss:.3} -> {pre_loss:.3} (uniform = {:.3})",
        f.pretrain,
        t0.elapsed().as_secs_f64(),
        (vocab as f32).ln()
    );
    let checkpoint = w.clone();

    // ---------------- Stage 2: FeedSign federated fine-tuning ----------------
    let task = tasks::find_task("synth-sst2").unwrap();
    let ft_train = tasks::generate(task, vocab, seq_len, 1024, 10);
    let ft_test = tasks::generate(task, vocab, seq_len, 256, 11);
    let mut client_shards = split(&ft_train, f.clients, Partition::Iid, 7);

    // every client starts from the shared checkpoint
    let mut client_w: Vec<Vec<f32>> = (0..f.clients).map(|_| checkpoint.clone()).collect();
    let mut client_rngs: Vec<Rng> =
        (0..f.clients).map(|k| Rng::new(0xE2E, k as u32 + 1)).collect();
    let mut ledger = Ledger::default();
    let mut orbit = Orbit::new("feedsign", 0, f.eta);
    let mut eval_rng = Rng::new(0xEE, 0);
    let mut eval_shard = Shard::new((0..ft_test.len()).collect());

    macro_rules! eval_now {
        ($w:expr) => {{
            let mut loss_sum = 0.0f32;
            let mut correct = 0u32;
            let mut total = 0u32;
            for _ in 0..4 {
                let batch = eval_shard.next_batch(&ft_test, be, &mut eval_rng);
                let (l, c) = model.eval($w, &batch)?;
                loss_sum += l;
                correct += c;
                total += be as u32;
            }
            (loss_sum / 4.0, correct as f32 / total as f32)
        }};
    }

    let (l0, a0) = eval_now!(&client_w[0]);
    println!(
        "\n[fft] K={} FeedSign on {} | initial: loss {l0:.4} acc {:.1}%",
        f.clients,
        task.name,
        a0 * 100.0
    );
    let t1 = std::time::Instant::now();
    for t in 0..f.rounds {
        let seed = t as u32;
        let mut signs = Vec::with_capacity(f.clients);
        for k in 0..f.clients {
            let batch = client_shards[k].next_batch(&ft_train, bp, &mut client_rngs[k]);
            let p = model.spsa_probe(&client_w[k], &batch, seed, f.mu)?;
            let sign = if p >= 0.0 { 1i8 } else { -1 };
            ledger.record(&Message::SignVote { sign });
            signs.push(sign);
        }
        let fsign = majority_sign(&signs);
        orbit.push_sign(fsign);
        for w in client_w.iter_mut() {
            ledger.record(&Message::GlobalSign { sign: fsign });
            model.update(w, seed, fsign as f32 * f.eta)?;
        }
        if (t + 1) % (f.rounds / 8).max(1) == 0 {
            let (l, a) = eval_now!(&client_w[0]);
            println!(
                "[fft] round {:>5}: loss {l:.4} acc {:.1}% | {} bits up, {} bits down",
                t + 1,
                a * 100.0,
                ledger.uplink_bits,
                ledger.downlink_bits
            );
        }
    }
    let fft_secs = t1.elapsed().as_secs_f64();
    let (l1, a1) = eval_now!(&client_w[0]);
    println!(
        "\n[fft] {} rounds in {fft_secs:.1}s ({:.0} ms/client-step): loss {l0:.4} -> {l1:.4}, acc {:.1}% -> {:.1}%",
        f.rounds,
        fft_secs * 1000.0 / (f.rounds * f.clients as u64) as f64,
        a0 * 100.0,
        a1 * 100.0
    );

    // comm ledger vs the FO alternative
    let d = model.entry.padded_size as u64;
    println!(
        "[comm] FeedSign total: {} bits ({} up / {} down)",
        ledger.total_bits(),
        ledger.uplink_bits,
        ledger.downlink_bits
    );
    println!(
        "[comm] FO-FedSGD at the same round count would move {:.2} GB; ratio {:.1e}x",
        (2 * 32 * d * f.rounds * f.clients as u64) as f64 / 8e9,
        (2 * 32 * d * f.rounds * f.clients as u64) as f64 / ledger.total_bits() as f64
    );
    let lm = LinkModel::mobile();
    println!(
        "[comm] projected mobile-link comm time: {:.2}s for the whole run",
        lm.seconds(&ledger)
    );

    // -------- Stage 3: orbit replay proves exact reconstruction --------
    let mut replayed = checkpoint;
    for (t, entry) in orbit.entries.iter().enumerate() {
        let feedsign::orbit::OrbitEntry::Sign(s) = entry else { unreachable!() };
        model.update(&mut replayed, t as u32, *s as f32 * f.eta)?;
    }
    anyhow::ensure!(replayed == client_w[0], "orbit replay diverged from the trained weights");
    let bytes = encode(&orbit).len();
    println!(
        "\n[orbit] replayed {} steps from a {} byte orbit — bit-exact reconstruction OK ({}x smaller than the {:.1} MB checkpoint)",
        orbit.len(),
        bytes,
        (model.entry.padded_size * 4) / bytes,
        model.entry.padded_size as f64 * 4.0 / 1e6
    );
    anyhow::ensure!(a1 > a0, "fine-tuning failed to improve accuracy");
    anyhow::ensure!(l1 < l0, "fine-tuning failed to reduce loss");
    println!("[e2e] PASS");
    Ok(())
}
