//! Quickstart: 5-client FeedSign federated fine-tuning in ~20 lines.
//!
//! Fine-tunes a classifier head on the synthetic CIFAR-10 analogue with
//! exactly 1 bit of uplink and 1 bit of downlink per client per round,
//! then prints the accuracy and the full communication ledger.
//!
//!     cargo run --release --example quickstart

use feedsign::config;

fn main() -> anyhow::Result<()> {
    // The built-in quickstart config: FeedSign, K=5, synth-cifar10,
    // 2000 rounds.  `feedsign init-config` prints it as editable TOML.
    let mut cfg = config::quickstart();
    cfg.verbose = true;

    let mut session = cfg.build_session()?;
    let result = session.run();

    println!(
        "\nFeedSign fine-tuned to {:.1}% accuracy (best {:.1}%) in {} rounds",
        result.final_acc * 100.0,
        result.best_acc() * 100.0,
        result.rounds
    );
    println!(
        "total communication: {} bits up + {} bits down for {} clients",
        result.ledger.uplink_bits,
        result.ledger.downlink_bits,
        session.clients.len()
    );
    println!(
        "the 1-bit orbit of this run replays to the exact final model: {} bytes",
        feedsign::orbit::encode(&session.orbit).len()
    );
    Ok(())
}
