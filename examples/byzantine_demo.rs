//! Byzantine resilience demo (Figure 3 shape): K = 25 clients, BK = 0..3
//! sign-flipping attackers, FeedSign vs ZO-FedSGD.
//!
//! The paper's claim (§4.3): ZO-FedSGD degrades as attackers are added,
//! FeedSign's majority vote holds until the Byzantine share approaches
//! K/2.  Run with `cargo run --release --example byzantine_demo`.

use feedsign::config::{ExperimentConfig, ModelSpec, TaskSpec};

fn cfg(algorithm: &str, byzantine: usize) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("byz-{algorithm}-{byzantine}"),
        model: ModelSpec::LinearProbe { dim: 128, classes: 10 },
        task: TaskSpec::SynthVision { name: "synth-cifar10".into(), train: 2500, test: 500 },
        algorithm: algorithm.into(),
        clients: 25,
        rounds: 3000,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        eval_batches: 6,
        eval_batch_size: 64,
        dirichlet_beta: None,
        byzantine_count: byzantine,
        // ZO-FedSGD's Table 5 attacker sends a random projection; for
        // FeedSign the same attacker degenerates to a (worst-case) flip.
        attack: Some(if algorithm == "feedsign" { "sign-flip".into() } else { "random-projection:5.0".into() }),
        c_g_noise: 0.0,
        participation: "full".into(),
        catchup: "off".into(),
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 5,
        verbose: false,
    }
}

fn main() -> anyhow::Result<()> {
    println!("K = 25 clients, sweeping BK = 0..3 Byzantine attackers\n");
    println!("{:>12} | {:>4} | {:>10} | {:>10}", "method", "BK", "final acc", "final loss");
    println!("{}", "-".repeat(48));
    let mut rows = std::collections::BTreeMap::new();
    for algorithm in ["zo-fedsgd", "feedsign"] {
        for bk in 0..=3usize {
            let mut session = cfg(algorithm, bk).build_session()?;
            let result = session.run();
            println!(
                "{algorithm:>12} | {bk:>4} | {:>9.1}% | {:>10.4}",
                result.final_acc * 100.0,
                result.final_loss
            );
            rows.insert((algorithm, bk), result.final_acc);
        }
    }
    let fs_drop = rows[&("feedsign", 0usize)] - rows[&("feedsign", 3usize)];
    let zo_drop = rows[&("zo-fedsgd", 0usize)] - rows[&("zo-fedsgd", 3usize)];
    println!(
        "\naccuracy drop with 3 attackers: FeedSign {:.1} pts vs ZO-FedSGD {:.1} pts",
        fs_drop * 100.0,
        zo_drop * 100.0
    );
    println!("(paper Fig. 3: FeedSign's convergence is not compromised until BK = 3)");
    Ok(())
}
