//! Orbit-based model storage (§D.1, Figures 5/6): fine-tune, persist the
//! orbit, replay it to a bit-identical model, and print the storage
//! ledger a model hub would see.
//!
//!     cargo run --release --example orbit_storage

use feedsign::config;
use feedsign::orbit::{decode, encode, storage_report};

fn main() -> anyhow::Result<()> {
    let mut cfg = config::quickstart();
    cfg.rounds = 5000;
    cfg.eval_every = 0;
    println!("fine-tuning ({} rounds of FeedSign, K={})...", cfg.rounds, cfg.clients);
    let mut session = cfg.build_session()?;
    let result = session.run();
    println!("final accuracy {:.1}%", result.final_acc * 100.0);

    // persist
    let bytes = encode(&session.orbit);
    let path = std::env::temp_dir().join("feedsign_demo.orbit");
    std::fs::write(&path, &bytes)?;
    println!("\norbit written to {} ({} bytes)", path.display(), bytes.len());

    // reload + replay from the shared checkpoint
    let orbit = decode(&std::fs::read(&path)?)?;
    let mut w = session.clients[0].engine.init_params(cfg.seed);
    orbit.replay(&mut w);
    assert_eq!(w.as_slice(), &*session.replica(0), "replay must be bit-exact");
    println!("replayed {} steps -> bit-identical to the trained model", orbit.len());

    // the storage ledger, at our scale and projected to the paper's
    let n_params = session.clients[0].engine.n_params();
    let rep = storage_report(&orbit, n_params);
    println!(
        "\nstorage ledger (this model): {} B orbit vs {} B checkpoint ({}x)",
        rep.orbit_bytes, rep.checkpoint_bytes, rep.ratio as u64
    );
    let opt13b = storage_report(&orbit, 13_000_000_000 / 4);
    println!(
        "projected to OPT-13B scale (paper §D.1): {} B orbit vs {:.0} GB checkpoint ({:.1e}x)",
        opt13b.orbit_bytes,
        opt13b.checkpoint_bytes as f64 / 1e9,
        opt13b.ratio
    );
    println!(
        "a model hub storing 600k fine-tunes as orbits: {:.1} MB instead of {:.1} PB",
        600_000.0 * opt13b.orbit_bytes as f64 / 1e6,
        600_000.0 * opt13b.checkpoint_bytes as f64 / 1e15
    );
    Ok(())
}
