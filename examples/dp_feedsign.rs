//! DP-FeedSign (Definition D.1): the exponential-mechanism vote, the
//! (eps, 0)-DP certificate, and the measured privacy-convergence
//! trade-off of Remark D.3.
//!
//!     cargo run --release --example dp_feedsign

use feedsign::config;
use feedsign::dp;

fn main() -> anyhow::Result<()> {
    let k = 5;
    println!("mechanism analysis, K = {k} clients:");
    println!("{:>8} | {:>14} | {:>12} | {:>12}", "epsilon", "worst ratio", "e^eps bound", "P(sign err)");
    println!("{}", "-".repeat(56));
    for &eps in &[0.25f32, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let ratio = dp::worst_case_ratio(k, eps);
        println!(
            "{eps:>8.2} | {ratio:>14.4} | {:>12.4} | {:>12.4}",
            (eps as f64).exp(),
            dp::dp_sign_error(k, k, eps)
        );
        assert!(ratio <= (eps as f64).exp() + 1e-9, "DP certificate violated");
    }
    println!("(worst-case probability ratio over adjacent vote vectors stays under e^eps — Theorem D.2)");

    println!("\nmeasured privacy-convergence trade-off (quickstart task, 1500 rounds):");
    println!("{:>12} | {:>10} | {:>10}", "epsilon", "final acc", "final loss");
    println!("{}", "-".repeat(38));
    for algo in ["dp-feedsign:0.5", "dp-feedsign:2.0", "dp-feedsign:8.0", "feedsign"] {
        let mut cfg = config::quickstart();
        cfg.algorithm = algo.into();
        cfg.rounds = 1500;
        cfg.eval_every = 0;
        let mut session = cfg.build_session()?;
        let result = session.run();
        println!(
            "{:>12} | {:>9.1}% | {:>10.4}",
            algo.strip_prefix("dp-feedsign:").unwrap_or("inf (plain)"),
            result.final_acc * 100.0,
            result.final_loss
        );
    }
    println!("\n(Remark D.3: eps -> 0 makes the vote a fair coin and stalls convergence;");
    println!(" larger eps buys back the majority vote at a weaker privacy guarantee)");
    Ok(())
}
