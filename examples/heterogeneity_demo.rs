//! Data-heterogeneity demo (Figure 2 shape): Dirichlet(beta) label-skew
//! sweep, FeedSign vs ZO-FedSGD.
//!
//! Theorem 3.11 / Remark 3.13: ZO-FedSGD's error floor scales with the
//! heterogeneity constants (sigma_h, c_g) while FeedSign's floor is
//! heterogeneity-independent — so as beta shrinks (more skew) and batch
//! noise is amplified (the paper's 1 + N(0,1) projection multiplier),
//! ZO-FedSGD loses more than FeedSign.
//!
//!     cargo run --release --example heterogeneity_demo

use feedsign::config::{ExperimentConfig, ModelSpec, TaskSpec};
use feedsign::data::partition::{label_skew, split, Partition};

fn cfg(algorithm: &str, beta: Option<f32>, c_g: f32) -> ExperimentConfig {
    ExperimentConfig {
        name: format!("het-{algorithm}-{beta:?}"),
        model: ModelSpec::LinearProbe { dim: 128, classes: 10 },
        task: TaskSpec::SynthVision { name: "synth-cifar10".into(), train: 2500, test: 500 },
        algorithm: algorithm.into(),
        clients: 25,
        rounds: 3000,
        eta: 2e-3,
        mu: 1e-3,
        batch_size: 16,
        eval_every: 0,
        eval_batches: 6,
        eval_batch_size: 64,
        dirichlet_beta: beta,
        byzantine_count: 0,
        attack: None,
        c_g_noise: c_g,
        participation: "full".into(),
        catchup: "off".into(),
        channel: "ideal".into(),
        link: "mobile".into(),
        deadline: 0.0,
        channel_seed: 0,
        threads: 0,
        replica_cache: 4,
        shards: 0,
        pretrain_rounds: 0,
        seed: 9,
        verbose: false,
    }
}

fn main() -> anyhow::Result<()> {
    println!("K = 25 clients, Dirichlet label-skew sweep (smaller beta = more skew)\n");
    println!(
        "{:>12} | {:>8} | {:>6} | {:>10} | {:>10}",
        "method", "beta", "skew", "final acc", "final loss"
    );
    println!("{}", "-".repeat(60));
    let sweeps: [(Option<f32>, f32); 3] = [(None, 0.0), (Some(1.0), 1.0), (Some(0.1), 1.0)];
    for algorithm in ["zo-fedsgd", "feedsign"] {
        for &(beta, c_g) in &sweeps {
            let c = cfg(algorithm, beta, c_g);
            // report the realized label skew of this sharding
            let (train, _) = c.datasets()?;
            let how = beta.map_or(Partition::Iid, |b| Partition::Dirichlet { beta: b });
            let skew = label_skew(&train, &split(&train, c.clients, how, c.seed));
            let mut session = c.build_session()?;
            let result = session.run();
            println!(
                "{algorithm:>12} | {:>8} | {skew:>6.2} | {:>9.1}% | {:>10.4}",
                beta.map_or("iid".to_string(), |b| format!("{b}")),
                result.final_acc * 100.0,
                result.final_loss
            );
        }
    }
    println!("\n(paper Fig. 2 / Table 4: FeedSign holds up better as skew + projection noise grow)");
    Ok(())
}
