"""linear_gelu Pallas kernel vs pure-jnp oracle (hypothesis shape sweeps)."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels.matmul import gelu_tanh, linear_act, linear_gelu, _pick_block
from compile.kernels.ref import linear_gelu_ref, linear_ref

SETTINGS = dict(max_examples=20, deadline=None)


def _rand(shape, seed):
    return jnp.array(np.random.RandomState(seed).randn(*shape).astype(np.float32))


class TestPickBlock:
    def test_power_of_two(self):
        assert _pick_block(1024, 128) == 128
        assert _pick_block(64, 128) == 64

    def test_awkward_dims(self):
        assert 320 % _pick_block(320, 128) == 0
        assert _pick_block(320, 128) >= 8
        assert 96 % _pick_block(96, 128) == 0

    def test_prime_dim_falls_back(self):
        b = _pick_block(97, 128)
        assert 97 % b == 0

    @given(dim=st.integers(1, 2048), cap=st.integers(1, 256))
    @settings(**SETTINGS)
    def test_always_divides(self, dim, cap):
        b = _pick_block(dim, cap)
        assert dim % b == 0 and 1 <= b <= max(cap, 1) or b == dim


class TestLinearGelu:
    @given(
        m=st.sampled_from([8, 32, 64, 128]),
        k=st.sampled_from([16, 64, 96, 320]),
        n=st.sampled_from([16, 64, 160, 256]),
        seed=st.integers(0, 1000),
    )
    @settings(**SETTINGS)
    def test_matches_ref(self, m, k, n, seed):
        x, w, b = _rand((m, k), seed), _rand((k, n), seed + 1), _rand((n,), seed + 2)
        out = linear_gelu(x, w, b, bm=32, bn=32, bk=32)
        expect = linear_gelu_ref(x, w, b)
        np.testing.assert_allclose(np.array(out), np.array(expect), atol=2e-4, rtol=2e-4)

    def test_affine_only(self):
        x, w, b = _rand((16, 32), 0), _rand((32, 48), 1), _rand((48,), 2)
        out = linear_act(x, w, b, activation=False, bm=8, bn=16, bk=16)
        np.testing.assert_allclose(
            np.array(out), np.array(linear_ref(x, w, b)), atol=1e-4, rtol=1e-4
        )

    def test_k_accumulation_order_invariant(self):
        """Different bk tilings accumulate the same result (fp tolerance)."""
        x, w, b = _rand((32, 128), 3), _rand((128, 64), 4), _rand((64,), 5)
        a = linear_gelu(x, w, b, bk=32)
        c = linear_gelu(x, w, b, bk=128)
        np.testing.assert_allclose(np.array(a), np.array(c), atol=1e-4, rtol=1e-4)

    def test_gelu_known_values(self):
        x = jnp.array([0.0, 1.0, -1.0, 10.0, -10.0], jnp.float32)
        g = np.array(gelu_tanh(x))
        assert abs(g[0]) < 1e-7
        assert abs(g[1] - 0.8412) < 1e-3
        assert abs(g[2] + 0.1588) < 1e-3
        assert abs(g[3] - 10.0) < 1e-4
        assert abs(g[4]) < 1e-4

    def test_model_shapes(self):
        """The exact shapes the `base` variant MLP feeds the kernel."""
        m, k, n = 8 * 128, 320, 1280
        x, w, b = _rand((m, k), 6), _rand((k, n), 7), _rand((n,), 8)
        out = linear_gelu(x, w, b)
        expect = linear_gelu_ref(x, w, b)
        np.testing.assert_allclose(np.array(out), np.array(expect), atol=5e-4, rtol=5e-4)
