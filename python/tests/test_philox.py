"""Kernel-vs-oracle tests for the shared-PRNG substrate (L1).

The Philox pipeline is the load-bearing wall of FeedSign: every party must
regenerate the same direction z from the same 32-bit seed.  hypothesis
sweeps seeds/shapes/blocks; u32 words are checked bit-exactly, float paths
to f32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import philox, ref

SETTINGS = dict(max_examples=25, deadline=None)


def _py_philox4x32(seed: int, counter: int, rounds: int = 10):
    """Independent big-int reference (no jnp) for the Philox words."""
    M0, M1 = 0xD2511F53, 0xCD9E8D57
    W0, W1 = 0x9E3779B9, 0xBB67AE85
    mask = 0xFFFFFFFF
    c = [counter & mask, 0, 0, 0]
    k0, k1 = seed & mask, philox.KEY1_INIT
    for _ in range(rounds):
        p0 = M0 * c[0]
        p1 = M1 * c[2]
        hi0, lo0 = (p0 >> 32) & mask, p0 & mask
        hi1, lo1 = (p1 >> 32) & mask, p1 & mask
        c = [(hi1 ^ c[1] ^ k0) & mask, lo1, (hi0 ^ c[3] ^ k1) & mask, lo0]
        k0 = (k0 + W0) & mask
        k1 = (k1 + W1) & mask
    return c


class TestPhiloxWords:
    @given(seed=st.integers(0, 2**32 - 1), counter=st.integers(0, 2**32 - 1))
    @settings(**SETTINGS)
    def test_words_match_bigint_reference(self, seed, counter):
        x0, x1, x2, x3 = ref.philox4x32_ref(seed, jnp.array([counter], jnp.uint32))
        expect = _py_philox4x32(seed, counter)
        assert [int(x0[0]), int(x1[0]), int(x2[0]), int(x3[0])] == expect

    def test_distinct_seeds_distinct_words(self):
        counters = jnp.arange(64, dtype=jnp.uint32)
        a = ref.philox4x32_ref(1, counters)
        b = ref.philox4x32_ref(2, counters)
        assert not np.array_equal(np.array(a[0]), np.array(b[0]))

    def test_deterministic(self):
        counters = jnp.arange(128, dtype=jnp.uint32)
        a = ref.philox4x32_ref(7, counters)
        b = ref.philox4x32_ref(7, counters)
        for x, y in zip(a, b):
            assert np.array_equal(np.array(x), np.array(y))


class TestPhiloxNormalKernel:
    @given(
        seed=st.integers(0, 2**31 - 1),
        log_n=st.integers(3, 12),
        log_block=st.integers(3, 10),
    )
    @settings(**SETTINGS)
    def test_kernel_matches_ref(self, seed, log_n, log_block):
        n, block = 1 << log_n, 1 << log_block
        z = philox.philox_normal(jnp.int32(seed), n, block=block)
        zr = ref.philox_normal_ref(seed, n)
        np.testing.assert_allclose(np.array(z), np.array(zr), atol=1e-6, rtol=1e-6)

    def test_block_independence(self):
        """z must not depend on the tiling — blocks derive global counters."""
        z1 = philox.philox_normal(jnp.int32(5), 4096, block=256)
        z2 = philox.philox_normal(jnp.int32(5), 4096, block=4096)
        np.testing.assert_array_equal(np.array(z1), np.array(z2))

    def test_unit_gaussian_moments(self):
        z = np.array(ref.philox_normal_ref(123, 1 << 18))
        assert abs(z.mean()) < 0.01
        assert abs(z.std() - 1.0) < 0.01
        # tails exist but are sane
        assert np.abs(z).max() < 7.0

    def test_no_degenerate_values(self):
        z = np.array(ref.philox_normal_ref(9, 1 << 16))
        assert np.isfinite(z).all()

    def test_rejects_non_multiple_of_4(self):
        with pytest.raises(ValueError):
            philox.philox_normal(jnp.int32(0), 1023)


class TestSpsaAxpyKernel:
    @given(
        seed=st.integers(0, 2**31 - 1),
        log_n=st.integers(3, 12),
        scale=st.floats(-10.0, 10.0, allow_nan=False, width=32),
    )
    @settings(**SETTINGS)
    def test_kernel_matches_ref(self, seed, log_n, scale):
        n = 1 << log_n
        w = jnp.arange(n, dtype=jnp.float32) * 0.01
        out = philox.spsa_axpy(w, jnp.int32(seed), jnp.float32(scale), block=256)
        expect = ref.spsa_axpy_ref(w, seed, scale)
        np.testing.assert_allclose(np.array(out), np.array(expect), atol=1e-5, rtol=1e-5)

    def test_zero_scale_identity(self):
        w = jnp.linspace(-1, 1, 512)
        out = philox.spsa_axpy(w.astype(jnp.float32), jnp.int32(3), jnp.float32(0.0))
        np.testing.assert_array_equal(np.array(out), np.array(w, np.float32))

    def test_plus_minus_symmetric(self):
        """probe+ and probe- must straddle w exactly: (wp + wm)/2 == w."""
        w = jnp.ones(1024, jnp.float32)
        wp = philox.spsa_axpy(w, jnp.int32(11), jnp.float32(0.5))
        wm = philox.spsa_axpy(w, jnp.int32(11), jnp.float32(-0.5))
        np.testing.assert_allclose(np.array((wp + wm) / 2), np.ones(1024), atol=1e-6)

    def test_same_z_as_philox_normal(self):
        """axpy's in-kernel noise == the standalone generator's z."""
        w = jnp.zeros(2048, jnp.float32)
        z_axpy = philox.spsa_axpy(w, jnp.int32(77), jnp.float32(1.0), block=512)
        z_gen = philox.philox_normal(jnp.int32(77), 2048, block=1024)
        np.testing.assert_allclose(np.array(z_axpy), np.array(z_gen), atol=1e-6)

    def test_awkward_length_blocks(self):
        """lengths that are multiples of 4 but not powers of two still tile."""
        n = 4 * 3 * 7 * 5  # 420
        w = jnp.zeros(n, jnp.float32)
        out = philox.spsa_axpy(w, jnp.int32(2), jnp.float32(1.0), block=256)
        expect = ref.philox_normal_ref(2, n)
        np.testing.assert_allclose(np.array(out), np.array(expect), atol=1e-6)
