"""AOT export smoke tests: lowering round-trips and the manifest contract."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile.aot import _export_fns, build_manifest, to_hlo_text

CFG = M.VARIANTS["tiny"]
ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


class TestLowering:
    def test_all_tiny_graphs_lower_to_hlo_text(self):
        for name, fn, example in _export_fns(CFG):
            text = to_hlo_text(jax.jit(fn).lower(*example))
            assert text.startswith("HloModule"), name
            assert "ENTRY" in text, name

    def test_probe_contains_no_custom_calls(self):
        """interpret=True must lower Pallas to plain HLO — a Mosaic
        custom-call would be unloadable by the CPU PJRT client."""
        name, fn, example = _export_fns(CFG)[0]
        text = to_hlo_text(jax.jit(fn).lower(*example))
        assert "custom-call" not in text.lower()

    def test_exported_probe_matches_eager(self):
        """Executing the lowered computation through jax must equal eager."""
        w = M.init_params(CFG)
        rng = np.random.RandomState(0)
        batch = jnp.array(rng.randint(0, CFG.vocab, (CFG.batch_probe, CFG.seq_len + 1)), jnp.int32)
        jit_p = jax.jit(lambda *a: M.spsa_probe(CFG, *a))
        eager = M.spsa_probe(CFG, w, batch, jnp.int32(1), jnp.float32(1e-3))
        jitted = jit_p(w, batch, jnp.int32(1), jnp.float32(1e-3))
        assert abs(float(eager) - float(jitted)) < 1e-5


class TestManifest:
    def test_build_manifest_schema(self):
        m = build_manifest(["tiny"])
        t = m["models"]["tiny"]
        assert t["n_params"] == CFG.n_params
        assert t["padded_size"] == CFG.padded_size
        assert len(t["segments"]) == len(CFG.segments())
        assert set(t["artifacts"]) == {
            "spsa_probe", "update", "loss", "eval", "fo_step", "grad_proj", "zvec"
        }

    def test_philox_vectors_present(self):
        m = build_manifest(["tiny"])
        ph = m["philox"]
        assert ph["rounds"] == 10
        assert len(ph["vectors"]) >= 3
        for v in ph["vectors"]:
            assert len(v["normals"]) == 16
            assert len(v["words"]) == 4

    @pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                        reason="artifacts not built")
    def test_written_manifest_matches_current_code(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            written = json.load(f)
        fresh = build_manifest(list(written["models"].keys()))
        assert written["philox"] == fresh["philox"]
        for name, mod in fresh["models"].items():
            assert written["models"][name]["n_params"] == mod["n_params"]
            assert written["models"][name]["padded_size"] == mod["padded_size"]

    @pytest.mark.skipif(not os.path.exists(os.path.join(ART, "manifest.json")),
                        reason="artifacts not built")
    def test_all_artifact_files_exist(self):
        with open(os.path.join(ART, "manifest.json")) as f:
            manifest = json.load(f)
        for mod in manifest["models"].values():
            for fname in mod["artifacts"].values():
                assert os.path.exists(os.path.join(ART, fname)), fname
