"""L2 model tests: shapes, SPSA semantics, determinism, FO step sanity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M

CFG = M.VARIANTS["tiny"]


@pytest.fixture(scope="module")
def w0():
    return M.init_params(CFG, seed=0)


@pytest.fixture(scope="module")
def batch():
    rng = np.random.RandomState(1)
    return jnp.array(rng.randint(0, CFG.vocab, (4, CFG.seq_len + 1)), jnp.int32)


class TestLayout:
    def test_param_count_matches_segments(self):
        total = sum(int(np.prod(s)) for _, s, _ in CFG.segments())
        assert total == CFG.n_params

    def test_padded_multiple(self):
        assert CFG.padded_size % M.PAD_MULTIPLE == 0
        assert CFG.padded_size >= CFG.n_params

    def test_unflatten_shapes(self, w0):
        p = M.unflatten(CFG, w0)
        assert p["embed"].shape == (CFG.vocab, CFG.d_model)
        assert p["layer0.w_qkv"].shape == (CFG.d_model, 3 * CFG.d_model)
        assert p["lnf_gain"].shape == (CFG.d_model,)

    def test_all_variants_consistent(self):
        for cfg in M.VARIANTS.values():
            assert cfg.d_model % cfg.n_heads == 0
            assert cfg.padded_size % 1024 == 0

    def test_init_deterministic(self):
        a = M.init_params(CFG, seed=3)
        b = M.init_params(CFG, seed=3)
        np.testing.assert_array_equal(np.array(a), np.array(b))

    def test_init_layernorm_gains_are_one(self, w0):
        p = M.unflatten(CFG, w0)
        np.testing.assert_array_equal(np.array(p["layer0.ln1_gain"]), 1.0)
        np.testing.assert_array_equal(np.array(p["layer1.ln2_bias"]), 0.0)


class TestForward:
    def test_logits_shape(self, w0, batch):
        logits = M.logits_fn(CFG, w0, batch[:, :-1], use_pallas=False)
        assert logits.shape == (4, CFG.seq_len, CFG.vocab)

    def test_pallas_and_jnp_paths_agree(self, w0, batch):
        a = M.loss_fn(CFG, w0, batch, use_pallas=True)
        b = M.loss_fn(CFG, w0, batch, use_pallas=False)
        assert abs(float(a) - float(b)) < 1e-4

    def test_initial_loss_near_uniform(self, w0, batch):
        # fresh init should predict ~ uniformly: loss ~ log(vocab)
        loss = float(M.loss_fn(CFG, w0, batch, use_pallas=False))
        assert abs(loss - np.log(CFG.vocab)) < 0.5

    def test_causality(self, w0):
        """Changing a future token must not change past logits."""
        rng = np.random.RandomState(2)
        t1 = rng.randint(0, CFG.vocab, (1, CFG.seq_len))
        t2 = t1.copy()
        t2[0, -1] = (t2[0, -1] + 1) % CFG.vocab
        l1 = M.logits_fn(CFG, w0, jnp.array(t1, jnp.int32), use_pallas=False)
        l2 = M.logits_fn(CFG, w0, jnp.array(t2, jnp.int32), use_pallas=False)
        np.testing.assert_allclose(
            np.array(l1[:, :-1]), np.array(l2[:, :-1]), atol=1e-5
        )


class TestSpsa:
    def test_probe_approximates_directional_derivative(self, w0, batch):
        p = float(M.spsa_probe(CFG, w0, batch, jnp.int32(3), jnp.float32(1e-3)))
        g = float(M.grad_proj(CFG, w0, batch, jnp.int32(3)))
        assert np.sign(p) == np.sign(g)
        assert abs(p - g) < 0.2 * max(abs(g), 1.0)

    def test_probe_mu_convergence(self, w0, batch):
        """Smaller mu -> probe closer to the exact jvp."""
        g = float(M.grad_proj(CFG, w0, batch, jnp.int32(5)))
        p_big = float(M.spsa_probe(CFG, w0, batch, jnp.int32(5), jnp.float32(1e-1)))
        p_small = float(M.spsa_probe(CFG, w0, batch, jnp.int32(5), jnp.float32(1e-3)))
        assert abs(p_small - g) <= abs(p_big - g) + 1e-4

    def test_update_then_inverse_restores(self, w0):
        """w -> update(seed, s) -> update(seed, -s) must round-trip exactly
        up to f32 add/sub (the orbit-replay invariant)."""
        w1 = M.update(CFG, w0, jnp.int32(9), jnp.float32(0.01))
        w2 = M.update(CFG, w1, jnp.int32(9), jnp.float32(-0.01))
        np.testing.assert_allclose(np.array(w2), np.array(w0), atol=1e-6)

    def test_update_direction_matches_zvec(self, w0):
        z = M.zvec(CFG, jnp.int32(4))
        w1 = M.update(CFG, w0, jnp.int32(4), jnp.float32(1.0))
        np.testing.assert_allclose(np.array(w0 - w1), np.array(z), atol=1e-5)

    def test_probe_deterministic(self, w0, batch):
        a = M.spsa_probe(CFG, w0, batch, jnp.int32(8), jnp.float32(1e-3))
        b = M.spsa_probe(CFG, w0, batch, jnp.int32(8), jnp.float32(1e-3))
        assert float(a) == float(b)

    def test_feedsign_vote_step_descends(self, w0, batch):
        """One FeedSign step with the correct sign must reduce the loss for a
        small enough step size (descent lemma, Theorem B.1)."""
        l0 = float(M.loss_fn(CFG, w0, batch, use_pallas=False))
        p = float(M.spsa_probe(CFG, w0, batch, jnp.int32(2), jnp.float32(1e-3)))
        f = 1.0 if p > 0 else -1.0
        w1 = M.update(CFG, w0, jnp.int32(2), jnp.float32(f * 1e-3))
        l1 = float(M.loss_fn(CFG, w1, batch, use_pallas=False))
        assert l1 < l0


class TestFoStep:
    def test_loss_decreases(self, w0, batch):
        w, loss0 = M.fo_step(CFG, w0, batch, jnp.float32(0.05))
        _, loss1 = M.fo_step(CFG, w, batch, jnp.float32(0.05))
        assert float(loss1) < float(loss0)

    def test_eval_counts_bounded(self, w0, batch):
        loss, correct = M.eval_fn(CFG, w0, batch)
        assert 0 <= int(correct) <= batch.shape[0]
        assert float(loss) > 0
