"""AOT export: lower every step graph for every model variant to HLO text.

This is the only place python touches the production path, and it runs once
(``make artifacts``).  Interchange format is **HLO text**, not serialized
HloModuleProto: jax >= 0.5 emits protos with 64-bit instruction ids which
the xla crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly (see
/opt/xla-example/README.md).

Outputs, under ``artifacts/``:

* ``{variant}_{fn}.hlo.txt`` — one per (variant, step graph), lowered with
  ``return_tuple=True`` (the rust runtime unwraps with ``to_tuple1`` /
  element extraction).
* ``manifest.json`` — everything the rust side needs to drive the
  executables blindly: shapes, parameter segment layout + init stds,
  hyperparameters, and Philox test vectors for cross-implementation parity
  (u32 words must match bit-exactly; normals to 1e-5).

Usage: ``python -m compile.aot --out-dir ../artifacts [--variants tiny,small]``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from .kernels import philox
from .kernels.ref import philox4x32_ref, philox_normal_ref


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _export_fns(cfg: M.ModelConfig):
    """(name, fn, example_args) for each exported graph of one variant."""
    P = cfg.padded_size
    w = jax.ShapeDtypeStruct((P,), jnp.float32)
    batch_p = jax.ShapeDtypeStruct((cfg.batch_probe, cfg.seq_len + 1), jnp.int32)
    batch_e = jax.ShapeDtypeStruct((cfg.batch_eval, cfg.seq_len + 1), jnp.int32)
    seed = jax.ShapeDtypeStruct((), jnp.int32)
    scalar = jax.ShapeDtypeStruct((), jnp.float32)

    return [
        ("spsa_probe",
         lambda w_, b_, s_, mu_: (M.spsa_probe(cfg, w_, b_, s_, mu_),),
         (w, batch_p, seed, scalar)),
        ("update",
         lambda w_, s_, st_: (M.update(cfg, w_, s_, st_),),
         (w, seed, scalar)),
        ("loss",
         lambda w_, b_: (M.loss_fn(cfg, w_, b_, use_pallas=False),),
         (w, batch_e)),
        ("eval",
         lambda w_, b_: M.eval_fn(cfg, w_, b_),
         (w, batch_e)),
        ("fo_step",
         lambda w_, b_, lr_: M.fo_step(cfg, w_, b_, lr_),
         (w, batch_p, scalar)),
        ("grad_proj",
         lambda w_, b_, s_: (M.grad_proj(cfg, w_, b_, s_),),
         (w, batch_p, seed)),
        ("zvec",
         lambda s_: (M.zvec(cfg, s_),),
         (seed,)),
    ]


def _philox_test_vectors() -> dict:
    """Recorded kernel outputs the rust PRNG must reproduce."""
    vectors = []
    for seed in (0, 1, 42, 2**31 - 1):
        counters = jnp.arange(4, dtype=jnp.uint32)
        x0, x1, x2, x3 = philox4x32_ref(seed, counters)
        normals = philox_normal_ref(seed, 16)
        vectors.append(
            {
                "seed": seed,
                "counters": [0, 1, 2, 3],
                "words": [
                    [int(v) for v in x0],
                    [int(v) for v in x1],
                    [int(v) for v in x2],
                    [int(v) for v in x3],
                ],
                "normals": [float(v) for v in normals],
            }
        )
    return {
        "key1_init": philox.KEY1_INIT,
        "rounds": 10,
        "vectors": vectors,
    }


def build_manifest(variants: list[str]) -> dict:
    out: dict = {"philox": _philox_test_vectors(), "models": {}}
    for name in variants:
        cfg = M.VARIANTS[name]
        segs = [
            {"name": n, "shape": list(shape), "init_std": std}
            for n, shape, std in cfg.segments()
        ]
        out["models"][name] = {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_layers": cfg.n_layers,
            "n_heads": cfg.n_heads,
            "seq_len": cfg.seq_len,
            "batch_probe": cfg.batch_probe,
            "batch_eval": cfg.batch_eval,
            "n_params": cfg.n_params,
            "padded_size": cfg.padded_size,
            "segments": segs,
            "artifacts": {
                fn: f"{name}_{fn}.hlo.txt"
                for fn, _, _ in _export_fns(cfg)
            },
        }
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--variants", default="tiny,small,base")
    args = ap.parse_args()
    variants = [v for v in args.variants.split(",") if v]
    os.makedirs(args.out_dir, exist_ok=True)

    for name in variants:
        cfg = M.VARIANTS[name]
        print(f"[aot] {name}: {cfg.n_params} params (padded {cfg.padded_size})")
        for fn_name, fn, example in _export_fns(cfg):
            lowered = jax.jit(fn).lower(*example)
            text = to_hlo_text(lowered)
            path = os.path.join(args.out_dir, f"{name}_{fn_name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"[aot]   {fn_name}: {len(text) / 1e6:.2f} MB -> {path}")

    manifest = build_manifest(variants)
    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"[aot] manifest.json written ({len(variants)} variants)")


if __name__ == "__main__":
    main()
