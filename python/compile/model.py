"""Layer-2 JAX model: a decoder-only transformer LM over a **flat parameter
vector**, plus the FeedSign step graphs built on it.

MeZO-style ZO optimization lives in flat parameter space — perturbation,
update and orbit replay all treat the model as one f32 vector — so the model
here is a pure function ``loss(w_flat, batch)``.  This also collapses the
PJRT ABI to a single buffer: the rust coordinator never learns the model's
internal structure (the paper's "PS can be small and task agnostic"
property, §D.2).

Exported step graphs (see ``aot.py``):

* ``spsa_probe(w, batch, seed, mu) -> p`` — the client step: regenerate the
  step direction ``z(seed)`` via the fused Pallas ``spsa_axpy`` kernel,
  evaluate the loss at ``w ± mu z`` (two forward passes, zero backprop) and
  return the scalar SPSA projection of Definition 3.1 (n = 1).
* ``update(w, seed, step) -> w'`` — apply ``w - step * z(seed)``; the rust
  PS folds the 1-bit vote into ``step = f * eta``.
* ``loss / eval`` — evaluation graphs (mean CE; last-position accuracy).
* ``fo_step(w, batch, lr) -> (w', loss)`` — the first-order FedSGD baseline
  (jax.grad; uses the jnp reference path since backprop is exactly what ZO
  avoids, and Pallas interpret kernels carry no VJP rule).
* ``grad_proj(w, batch, seed) -> z . grad L`` — the *true* directional
  derivative via forward-mode jvp, used by the Appendix-E sign-reversing
  probability study (Fig. 8/9).

The flat vector is padded to a multiple of 1024 so the Philox/AXPY kernels
tile evenly; the dead tail is perturbed like everything else (harmless: no
segment reads it) which keeps orbit replay bit-exact.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import philox
from .kernels.matmul import gelu_tanh, linear_act

PAD_MULTIPLE = 1024


@dataclass(frozen=True)
class ModelConfig:
    """Architecture hyperparameters for one exported model variant."""

    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int
    seq_len: int
    batch_probe: int = 8
    batch_eval: int = 32

    @property
    def d_ff(self) -> int:
        return 4 * self.d_model

    @property
    def head_dim(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def segments(self) -> list[tuple[str, tuple[int, ...], float]]:
        """(name, shape, init_std) for every parameter segment, in flat order.

        The rust side reads this layout from the manifest to build the
        initial parameter vector with its own Philox stream; init_std == 0.0
        means zeros, == 1.0 on *_gain means ones (layernorm gains).
        """
        d, f, v, t = self.d_model, self.d_ff, self.vocab, self.seq_len
        w_std = 0.02
        segs: list[tuple[str, tuple[int, ...], float]] = [
            ("embed", (v, d), w_std),
            ("pos", (t, d), w_std),
        ]
        for l in range(self.n_layers):
            p = f"layer{l}."
            segs += [
                (p + "ln1_gain", (d,), 1.0),
                (p + "ln1_bias", (d,), 0.0),
                (p + "w_qkv", (d, 3 * d), w_std),
                (p + "b_qkv", (3 * d,), 0.0),
                (p + "w_attn_out", (d, d), w_std),
                (p + "b_attn_out", (d,), 0.0),
                (p + "ln2_gain", (d,), 1.0),
                (p + "ln2_bias", (d,), 0.0),
                (p + "w_mlp_in", (d, f), w_std),
                (p + "b_mlp_in", (f,), 0.0),
                (p + "w_mlp_out", (f, d), w_std),
                (p + "b_mlp_out", (d,), 0.0),
            ]
        segs += [("lnf_gain", (d,), 1.0), ("lnf_bias", (d,), 0.0)]
        return segs

    @property
    def n_params(self) -> int:
        total = 0
        for _, shape, _ in self.segments():
            n = 1
            for s in shape:
                n *= s
            total += n
        return total

    @property
    def padded_size(self) -> int:
        n = self.n_params
        return ((n + PAD_MULTIPLE - 1) // PAD_MULTIPLE) * PAD_MULTIPLE


# The exported variants.  `base` sits at the ~11M low end of the paper's
# 11M-13B model range; smaller variants keep tests and the interpret-mode
# e2e driver fast.
VARIANTS: dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in [
        ModelConfig("tiny", vocab=256, d_model=64, n_layers=2, n_heads=4, seq_len=32,
                    batch_probe=4, batch_eval=16),
        ModelConfig("small", vocab=256, d_model=128, n_layers=4, n_heads=8, seq_len=64,
                    batch_probe=8, batch_eval=32),
        ModelConfig("base", vocab=512, d_model=320, n_layers=10, n_heads=8, seq_len=128,
                    batch_probe=8, batch_eval=32),
    ]
}


def unflatten(cfg: ModelConfig, w: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """Slice the flat vector into named parameter arrays (static offsets)."""
    params: dict[str, jnp.ndarray] = {}
    off = 0
    for name, shape, _ in cfg.segments():
        n = 1
        for s in shape:
            n *= s
        params[name] = w[off : off + n].reshape(shape)
        off += n
    return params


def _layernorm(x: jnp.ndarray, gain: jnp.ndarray, bias: jnp.ndarray) -> jnp.ndarray:
    mu = x.mean(axis=-1, keepdims=True)
    var = ((x - mu) ** 2).mean(axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5) * gain + bias


def _attention(cfg: ModelConfig, x: jnp.ndarray, p: dict, prefix: str) -> jnp.ndarray:
    b, t, d = x.shape
    h, hd = cfg.n_heads, cfg.head_dim
    qkv = x @ p[prefix + "w_qkv"] + p[prefix + "b_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, t, h, hd).transpose(0, 2, 1, 3)
    scores = (q @ k.transpose(0, 1, 3, 2)) / jnp.sqrt(jnp.float32(hd))
    mask = jnp.tril(jnp.ones((t, t), dtype=jnp.bool_))
    scores = jnp.where(mask[None, None], scores, jnp.float32(-1e30))
    attn = jax.nn.softmax(scores, axis=-1)
    out = (attn @ v).transpose(0, 2, 1, 3).reshape(b, t, d)
    return out @ p[prefix + "w_attn_out"] + p[prefix + "b_attn_out"]


def _mlp(cfg: ModelConfig, x: jnp.ndarray, p: dict, prefix: str, use_pallas: bool):
    b, t, d = x.shape
    if use_pallas:
        h = linear_act(x.reshape(b * t, d), p[prefix + "w_mlp_in"],
                       p[prefix + "b_mlp_in"], activation=True)
        o = linear_act(h, p[prefix + "w_mlp_out"], p[prefix + "b_mlp_out"],
                       activation=False)
        return o.reshape(b, t, d)
    h = gelu_tanh(x @ p[prefix + "w_mlp_in"] + p[prefix + "b_mlp_in"])
    return h @ p[prefix + "w_mlp_out"] + p[prefix + "b_mlp_out"]


def logits_fn(cfg: ModelConfig, w: jnp.ndarray, tokens: jnp.ndarray,
              use_pallas: bool = True) -> jnp.ndarray:
    """Forward pass: tokens i32[B, T] -> logits f32[B, T, V] (tied head)."""
    p = unflatten(cfg, w)
    x = p["embed"][tokens] + p["pos"][None, : tokens.shape[1]]
    for l in range(cfg.n_layers):
        pre = f"layer{l}."
        x = x + _attention(cfg, _layernorm(x, p[pre + "ln1_gain"], p[pre + "ln1_bias"]), p, pre)
        x = x + _mlp(cfg, _layernorm(x, p[pre + "ln2_gain"], p[pre + "ln2_bias"]), p, pre, use_pallas)
    x = _layernorm(x, p["lnf_gain"], p["lnf_bias"])
    return x @ p["embed"].T


def loss_fn(cfg: ModelConfig, w: jnp.ndarray, batch: jnp.ndarray,
            use_pallas: bool = True) -> jnp.ndarray:
    """Mean next-token cross-entropy.  batch: i32[B, T+1]."""
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits = logits_fn(cfg, w, tokens, use_pallas)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return nll.mean()


def eval_fn(cfg: ModelConfig, w: jnp.ndarray, batch: jnp.ndarray):
    """(mean loss, # correct last-position predictions).

    Synthetic classification tasks put the label token in the final
    position, so last-position argmax accuracy is the task metric.
    """
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits = logits_fn(cfg, w, tokens, use_pallas=False)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    pred_last = jnp.argmax(logits[:, -1, :], axis=-1)
    correct = (pred_last == targets[:, -1]).astype(jnp.int32).sum()
    return nll.mean(), correct


def spsa_probe(cfg: ModelConfig, w: jnp.ndarray, batch: jnp.ndarray,
               seed: jnp.ndarray, mu: jnp.ndarray) -> jnp.ndarray:
    """SPSA projection p = (L(w + mu z) - L(w - mu z)) / (2 mu), n = 1.

    Both perturbed parameter vectors come from the fused Pallas
    ``spsa_axpy`` kernel, so the direction z is bit-identical to the one
    ``update`` later applies — the invariant FeedSign's 1-bit protocol
    rests on.
    """
    wp = philox.spsa_axpy(w, seed, mu)
    wm = philox.spsa_axpy(w, seed, -mu)
    lp = loss_fn(cfg, wp, batch, use_pallas=True)
    lm = loss_fn(cfg, wm, batch, use_pallas=True)
    return (lp - lm) / (2.0 * mu)


def update(cfg: ModelConfig, w: jnp.ndarray, seed: jnp.ndarray,
           step: jnp.ndarray) -> jnp.ndarray:
    """w' = w - step * z(seed).  step = f * eta (FeedSign) or mean-projection
    * eta (ZO-FedSGD); the sign/aggregation logic lives in rust."""
    return philox.spsa_axpy(w, seed, -step)


def fo_step(cfg: ModelConfig, w: jnp.ndarray, batch: jnp.ndarray, lr: jnp.ndarray):
    """First-order FedSGD baseline step (and the pretraining engine)."""
    loss, grad = jax.value_and_grad(lambda ww: loss_fn(cfg, ww, batch, use_pallas=False))(w)
    return w - lr * grad, loss


def grad_proj(cfg: ModelConfig, w: jnp.ndarray, batch: jnp.ndarray,
              seed: jnp.ndarray) -> jnp.ndarray:
    """Exact directional derivative z(seed) . grad L(w, batch) via jvp.

    Forward-mode only — this is the mu -> 0 limit of the SPSA projection and
    the ground truth for the Appendix-E sign-reversing study.
    """
    z = philox.philox_normal(seed, w.shape[0])
    _, jvp_val = jax.jvp(lambda ww: loss_fn(cfg, ww, batch, use_pallas=False), (w,), (z,))
    return jvp_val


def zvec(cfg: ModelConfig, seed: jnp.ndarray) -> jnp.ndarray:
    """The raw step direction z(seed) — exported for cross-implementation
    parity tests between the Pallas kernel and rust simkit."""
    return philox.philox_normal(seed, cfg.padded_size)


def init_params(cfg: ModelConfig, seed: int = 0) -> jnp.ndarray:
    """Reference initial flat parameter vector (python-side tests/pretrain).

    Segment-wise: weights ~ std * N(0,1) from the same Philox stream the
    rust initializer uses (seed offset = segment index), gains = 1, biases
    = 0.  Keeping init generation counter-based makes python and rust
    checkpoints interchangeable.
    """
    from .kernels.ref import philox_normal_ref

    parts = []
    for idx, (_, shape, std) in enumerate(cfg.segments()):
        n = 1
        for s in shape:
            n *= s
        if std == 1.0 and len(shape) == 1:  # layernorm gain
            parts.append(jnp.ones((n,), jnp.float32))
        elif std == 0.0:
            parts.append(jnp.zeros((n,), jnp.float32))
        else:
            m = ((n + 3) // 4) * 4
            z = philox_normal_ref(seed * 65536 + idx, m)[:n]
            parts.append(std * z)
    flat = jnp.concatenate(parts)
    pad = cfg.padded_size - flat.shape[0]
    return jnp.concatenate([flat, jnp.zeros((pad,), jnp.float32)])
