"""Layer-1 Pallas kernels for FeedSign's shared-PRNG substrate.

FeedSign's core trick is that every party (PS + all clients) can regenerate
the SPSA perturbation direction ``z ~ N(0, I_d)`` *bit-identically* from a
32-bit step seed, so the direction itself never travels over the network.
These kernels make that substrate explicit:

* ``philox_normal(seed, n)`` — counter-based Philox-4x32-10 PRNG followed by
  a Box-Muller transform, producing the standard-normal direction ``z``.
  Counter-based means element ``i`` of ``z`` is a pure function of
  ``(seed, i)``: each Pallas grid block derives its own counters with
  ``broadcasted_iota`` and generates exactly the tile of ``z`` it needs.

* ``spsa_axpy(w, seed, scale)`` — the FeedSign hot-op ``w + scale * z(seed)``
  with the noise generation *fused* into the AXPY.  On a real TPU this is
  the difference between inference-level memory and 2x memory: ``z`` is
  never materialised in HBM, each VMEM tile of it is generated exactly
  where it is consumed (BlockSpec expresses the HBM<->VMEM schedule).
  The same op implements all three uses per federated step:
  probe+ (``scale=+mu``), probe- (``scale=-mu``) and the model update
  (``scale=-f*eta`` with ``f`` the 1-bit global vote).

Kernels are lowered with ``interpret=True`` (CPU PJRT cannot execute Mosaic
custom-calls); correctness is pinned against the pure-jnp oracles in
``ref.py`` by ``python/tests/test_philox.py`` (hypothesis sweeps) and the
rust implementation in ``rust/src/simkit/prng.rs`` replays the manifest's
test vectors bit-exactly at the u32 level.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Philox-4x32 round constants (Salmon et al., SC'11), as python ints so they
# embed as literals inside Pallas kernel traces (closure-captured jnp arrays
# are rejected by pallas_call).
PHILOX_M0 = 0xD2511F53
PHILOX_M1 = 0xCD9E8D57
PHILOX_W0 = 0x9E3779B9  # golden ratio
PHILOX_W1 = 0xBB67AE85  # sqrt(3) - 1
KEY1_INIT = 0xCAFEF00D
_MASK32 = 0xFFFFFFFF

# Default block: big enough that the interpret-mode grid loop overhead is
# negligible even for multi-million-parameter vectors.
DEFAULT_BLOCK = 1 << 16

TWO_PI = 6.283185307179586


def _u32(x: int) -> jnp.ndarray:
    return jnp.uint32(x & _MASK32)


def _mulhilo_const(a: int, b: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """Exact 32x32 -> (hi, lo) multiply of a *constant* by a u32 vector.

    Built from 16-bit limbs so it needs no u64 support (jax_enable_x64 stays
    off and the lowered HLO is pure u32 arithmetic, matching the rust
    implementation word for word).
    """
    alo, ahi = a & 0xFFFF, (a >> 16) & 0xFFFF
    blo = b & _u32(0xFFFF)
    bhi = b >> jnp.uint32(16)
    ll = _u32(alo) * blo                     # <= (2^16-1)^2, fits u32
    lh = _u32(alo) * bhi
    hl = _u32(ahi) * blo
    hh = _u32(ahi) * bhi
    mid = lh + hl                            # may wrap: detect carry
    mid_carry = (mid < lh).astype(jnp.uint32)
    lo = ll + (mid << jnp.uint32(16))
    lo_carry = (lo < ll).astype(jnp.uint32)
    hi = hh + (mid >> jnp.uint32(16)) + (mid_carry << jnp.uint32(16)) + lo_carry
    return hi, lo


def philox4x32(seed_u32: jnp.ndarray, counters: jnp.ndarray, rounds: int = 10):
    """Philox-4x32 over a vector of counter indices.

    Counter block for index ``i`` is ``(i, 0, 0, 0)``; the key is
    ``(seed, KEY1_INIT)``.  Returns four u32 vectors, one random word per
    counter per lane.  Pure function usable both inside Pallas kernels and
    in the jnp reference.
    """
    c0 = counters.astype(jnp.uint32)
    zeros = jnp.zeros_like(c0)
    c1, c2, c3 = zeros, zeros, zeros
    k0 = jnp.asarray(seed_u32).astype(jnp.uint32)
    k1_int = KEY1_INIT  # key lane 1 never depends on the seed: fold at trace time
    for r in range(rounds):
        hi0, lo0 = _mulhilo_const(PHILOX_M0, c0)
        hi1, lo1 = _mulhilo_const(PHILOX_M1, c2)
        c0, c1, c2, c3 = hi1 ^ c1 ^ k0, lo1, hi0 ^ c3 ^ _u32(k1_int), lo0
        k0 = k0 + _u32(PHILOX_W0)
        k1_int = (k1_int + PHILOX_W1) & _MASK32
    return c0, c1, c2, c3


def _u32_to_unit(x: jnp.ndarray) -> jnp.ndarray:
    """Map u32 -> float32 in the open interval (0, 1).

    ``(x >> 8) * 2^-24 + 2^-25``: 24 mantissa-exact bits, never 0 or 1, and
    bit-reproducible across jnp / rust f32 (single mul + add, both exact at
    these magnitudes' rounding behaviour).
    """
    return (x >> jnp.uint32(8)).astype(jnp.float32) * jnp.float32(
        1.0 / (1 << 24)
    ) + jnp.float32(1.0 / (1 << 25))


def _box_muller(u1: jnp.ndarray, u2: jnp.ndarray):
    r = jnp.sqrt(jnp.float32(-2.0) * jnp.log(u1))
    theta = jnp.float32(TWO_PI) * u2
    return r * jnp.cos(theta), r * jnp.sin(theta)


def _normals_from_counters(seed_u32: jnp.ndarray, counters: jnp.ndarray) -> jnp.ndarray:
    """4 standard normals per counter, interleaved [z0, z1, z2, z3] per lane."""
    x0, x1, x2, x3 = philox4x32(seed_u32, counters)
    za, zb = _box_muller(_u32_to_unit(x0), _u32_to_unit(x1))
    zc, zd = _box_muller(_u32_to_unit(x2), _u32_to_unit(x3))
    return jnp.stack([za, zb, zc, zd], axis=-1).reshape(-1)


def _philox_normal_kernel(seed_ref, o_ref, *, block: int):
    """One grid block generates ``block`` normals for its slice of z."""
    pid = pl.program_id(0)
    lanes = block // 4
    base = (pid * lanes).astype(jnp.uint32)
    counters = base + jax.lax.broadcasted_iota(jnp.uint32, (lanes,), 0)
    o_ref[...] = _normals_from_counters(seed_ref[0].astype(jnp.uint32), counters)


def philox_normal(seed: jnp.ndarray, n: int, block: int = DEFAULT_BLOCK) -> jnp.ndarray:
    """Generate ``z ~ N(0, I_n)`` from a scalar int32 seed (Pallas kernel).

    ``n`` must be a multiple of 4; the grid pads to a multiple of ``block``
    internally and slices the tail off.
    """
    if n % 4 != 0:
        raise ValueError(f"n must be a multiple of 4, got {n}")
    block = min(block, _round_up(n, 4))
    padded = _round_up(n, block)
    grid = padded // block
    seed_arr = jnp.reshape(seed, (1,)).astype(jnp.int32)
    out = pl.pallas_call(
        functools.partial(_philox_normal_kernel, block=block),
        grid=(grid,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((padded,), jnp.float32),
        interpret=True,
    )(seed_arr)
    return out[:n]


def _spsa_axpy_kernel(seed_ref, scale_ref, w_ref, o_ref, *, block: int):
    """Fused noise-gen + AXPY: o = w + scale * z(seed) for this tile.

    The tile of z is regenerated from (seed, tile offset) in VMEM — z never
    exists as a full array.  ``scale`` is a runtime scalar so the same
    compiled executable serves probe+/probe-/update.
    """
    pid = pl.program_id(0)
    lanes = block // 4
    base = (pid * lanes).astype(jnp.uint32)
    counters = base + jax.lax.broadcasted_iota(jnp.uint32, (lanes,), 0)
    z = _normals_from_counters(seed_ref[0].astype(jnp.uint32), counters)
    o_ref[...] = w_ref[...] + scale_ref[0] * z


def spsa_axpy(
    w: jnp.ndarray, seed: jnp.ndarray, scale: jnp.ndarray, block: int = DEFAULT_BLOCK
) -> jnp.ndarray:
    """``w + scale * z(seed)`` with fused noise generation (Pallas kernel).

    ``w.shape = (n,)`` with ``n % 4 == 0`` (flat-parameter layout pads to a
    multiple of the block size anyway — see model.ModelConfig.padded_size).
    """
    (n,) = w.shape
    if n % 4 != 0:
        raise ValueError(f"len(w) must be a multiple of 4, got {n}")
    block = min(block, n)
    if n % block != 0:
        # fall back to the largest power-of-two divisor <= block
        b = 4
        while b * 2 <= block and n % (b * 2) == 0:
            b *= 2
        block = b
    grid = n // block
    seed_arr = jnp.reshape(seed, (1,)).astype(jnp.int32)
    scale_arr = jnp.reshape(scale, (1,)).astype(jnp.float32)
    return pl.pallas_call(
        functools.partial(_spsa_axpy_kernel, block=block),
        grid=(grid,),
        in_specs=[
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
            pl.BlockSpec((block,), lambda i: (i,)),
        ],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(seed_arr, scale_arr, w)


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
