"""Layer-1 Pallas kernel for the transformer MLP hot-spot: tiled
``gelu(x @ w + b)``.

The SPSA probe is two inference passes, and in a decoder-only transformer
~2/3 of the FLOPs live in the MLP block, so this is the MXU target.  The
kernel is written the TPU way: a 3-D grid ``(M/bm, N/bn, K/bk)`` where each
``(i, j)`` output tile accumulates partial products over the ``k`` axis in
the (revisited) output block, and the bias + GeLU epilogue fires only on the
last ``k`` step.  Block shapes default to 128x128x(<=128): one MXU-shaped
f32 tile of x, w and the accumulator live in VMEM at a time
(3 * 128*128 * 4B = 192 KiB << 16 MiB VMEM), leaving headroom for
double-buffering the HBM streams.

``interpret=True`` for CPU-PJRT execution; the pure-jnp oracle is
``ref.linear_gelu_ref`` and hypothesis sweeps shapes in
``python/tests/test_matmul.py``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

SQRT_2_OVER_PI = 0.7978845608028654


def gelu_tanh(x: jnp.ndarray) -> jnp.ndarray:
    """tanh-approximation GeLU (matches the rust simkit implementation)."""
    x3 = x * x * x
    return 0.5 * x * (1.0 + jnp.tanh(SQRT_2_OVER_PI * (x + 0.044715 * x3)))


def _linear_gelu_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, activation: bool):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...] + b_ref[...][None, :]
        o_ref[...] = gelu_tanh(acc) if activation else acc


def _pick_block(dim: int, cap: int) -> int:
    """Largest divisor of ``dim`` that is <= cap, preferring powers of two."""
    b = 1
    while b * 2 <= cap and dim % (b * 2) == 0:
        b *= 2
    if b >= 8 or dim < 8:
        return b
    # dim has an awkward factorisation; fall back to any divisor <= cap
    for cand in range(min(cap, dim), 0, -1):
        if dim % cand == 0:
            return cand
    return dim


def linear_act(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    *,
    activation: bool = True,
    bm: int = 128,
    bn: int = 128,
    bk: int = 128,
) -> jnp.ndarray:
    """``gelu(x @ w + b)`` (or affine only with ``activation=False``).

    x: (M, K), w: (K, N), b: (N,) -> (M, N).  Block sizes are clamped to
    divisors of the respective dims so arbitrary model widths work.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,), (x.shape, w.shape, b.shape)
    bm, bn, bk = _pick_block(m, bm), _pick_block(n, bn), _pick_block(k, bk)
    nk = k // bk
    return pl.pallas_call(
        functools.partial(_linear_gelu_kernel, nk=nk, activation=activation),
        grid=(m // bm, n // bn, nk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w, b)


def linear_gelu(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray, **kw) -> jnp.ndarray:
    return linear_act(x, w, b, activation=True, **kw)
