"""Pure-jnp correctness oracles for the Pallas kernels.

Every kernel in this package has a reference implementation here that uses
only standard jnp ops (no Pallas).  pytest/hypothesis compare kernel output
against these oracles:

* u32 Philox words must match **bit-exactly** (pure integer pipeline);
* normals / axpy / linear_gelu match to float32 tolerance (transcendental
  functions may differ in the last ulp between the interpret-mode kernel
  and the fused XLA graph).

The rust simkit PRNG (``rust/src/simkit/prng.rs``) is pinned against the
same construction via test vectors recorded into ``artifacts/manifest.json``.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import philox as _ph
from .matmul import gelu_tanh


def philox4x32_ref(seed: int, counters: jnp.ndarray, rounds: int = 10):
    """Reference Philox words — same pure function the kernel calls, exposed
    for bit-level tests and manifest vector generation."""
    return _ph.philox4x32(jnp.uint32(seed), counters.astype(jnp.uint32), rounds)


def philox_normal_ref(seed, n: int) -> jnp.ndarray:
    """z ~ N(0, I_n) without Pallas: whole counter range in one jnp sweep."""
    lanes = (n + 3) // 4
    counters = jnp.arange(lanes, dtype=jnp.uint32)
    z = _ph._normals_from_counters(jnp.asarray(seed).astype(jnp.uint32), counters)
    return z[:n]


def spsa_axpy_ref(w: jnp.ndarray, seed, scale) -> jnp.ndarray:
    return w + jnp.float32(scale) * philox_normal_ref(seed, w.shape[0])


def linear_gelu_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return gelu_tanh(x @ w + b[None, :])


def linear_ref(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    return x @ w + b[None, :]
